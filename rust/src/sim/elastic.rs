//! Elastic-trace simulator: join/leave events mid-run, exact work
//! retention, transition-waste accounting.
//!
//! Semantics (DESIGN.md §Substitutions):
//!
//! * Completed subtask outputs are already at the master — they survive the
//!   departure of their worker and any re-allocation.
//! * Work on the *current* (incomplete) subtask is abandoned on a
//!   re-allocation or preemption; that abandonment is what the transition-
//!   waste metric prices.
//! * CEC/MLCEC re-subdivide at each event (granularity = current N, as in
//!   the paper's Fig. 1). Retention across granularities is exact because
//!   completed work is tracked as *row intervals* per code slot
//!   (`intervals::IntervalSet`), and a row of the output is recoverable
//!   once K slots cover it.
//! * BICEC never re-allocates: slots own static subtask ranges
//!   (`Scheme::allocate_active`), so its transition waste is identically 0.

use std::collections::HashSet;

use crate::tas::{transition, Allocation, RecoveryRule, Scheme};
use crate::workload::JobSpec;

use super::intervals::{min_coverage, IntervalSet};
use super::trace::{ElasticTrace, EventKind};
use super::{CostModel, WorkerSpeeds};

#[derive(Clone, Debug)]
pub struct TraceOutcome {
    pub computation_time: f64,
    pub decode_time: f64,
    /// Total transition waste (task-fraction units, see tas::transition).
    pub transition_waste: f64,
    /// Number of re-allocations performed (0 for BICEC).
    pub reallocations: usize,
    /// Subtask completions delivered to the master.
    pub completions: u64,
}

impl TraceOutcome {
    pub fn finishing_time(&self) -> f64 {
        self.computation_time + self.decode_time
    }
}

/// How surviving workers are matched to the new allocation's lists at an
/// elastic event.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Reassign {
    /// Positional: surviving worker `i` takes list `i` (the schemes' naive
    /// behaviour).
    #[default]
    Identity,
    /// Waste-minimising greedy matching (tas::reassign, after Dau et al.
    /// [10]); never worse than Identity.
    MaxOverlap,
}

#[derive(Debug)]
pub enum SimError {
    Unrecoverable { at: f64, reason: String },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Unrecoverable { at, reason } => {
                write!(f, "unrecoverable at t={at}: {reason}")
            }
        }
    }
}

impl std::error::Error for SimError {}

/// Per-active-worker run state within one allocation epoch.
struct WorkerState {
    slot: usize,
    /// Next item index in its epoch list.
    pointer: usize,
    /// Completion time of the item currently in flight (f64::INFINITY when
    /// the list is exhausted).
    next_done: f64,
}

pub fn simulate_trace(
    scheme: &dyn Scheme,
    trace: &ElasticTrace,
    job: JobSpec,
    cost: &CostModel,
    speeds: &WorkerSpeeds,
) -> Result<TraceOutcome, SimError> {
    simulate_trace_with(scheme, trace, job, cost, speeds, Reassign::Identity)
}

/// `simulate_trace` with an explicit re-assignment policy.
pub fn simulate_trace_with(
    scheme: &dyn Scheme,
    trace: &ElasticTrace,
    job: JobSpec,
    cost: &CostModel,
    speeds: &WorkerSpeeds,
    reassign: Reassign,
) -> Result<TraceOutcome, SimError> {
    trace.validate().map_err(|e| SimError::Unrecoverable { at: 0.0, reason: e })?;
    assert!(speeds.n_max() >= trace.n_max);

    let mut active: Vec<usize> = (0..trace.n_initial).collect();
    // Row coverage per slot (PerSet schemes).
    let mut coverage: Vec<IntervalSet> = vec![IntervalSet::new(); trace.n_max];
    // Completed global ids (Global schemes).
    let mut done_ids: HashSet<usize> = HashSet::new();

    let mut waste = 0.0;
    let mut reallocations = 0usize;
    let mut completions = 0u64;
    let mut t = 0.0f64;
    let mut ev_idx = 0usize;

    let mut alloc = scheme.allocate_active(&active);
    let mut workers = init_workers(scheme, &alloc, &active, job, cost, speeds, &coverage, &done_ids, t);

    let decode_time = cost.decode_time(scheme.decode_ops(job.u, job.v));

    loop {
        // Earliest in-flight completion.
        let (next_t, who) = workers
            .iter()
            .enumerate()
            .map(|(i, w)| (w.next_done, i))
            .fold((f64::INFINITY, usize::MAX), |acc, x| if x.0 < acc.0 { x } else { acc });
        let next_event_t = trace.events.get(ev_idx).map(|e| e.time).unwrap_or(f64::INFINITY);

        if next_t.is_infinite() && next_event_t.is_infinite() {
            return Err(SimError::Unrecoverable {
                at: t,
                reason: "all workers exhausted before recovery".into(),
            });
        }

        if next_t <= next_event_t {
            // A subtask completes.
            t = next_t;
            let slot = workers[who].slot;
            let item = alloc.lists[who][workers[who].pointer];
            completions += 1;
            let recovered = match alloc.rule {
                RecoveryRule::PerSet { sets, k } => {
                    let g = sets as f64;
                    coverage[slot]
                        .insert(item.group as f64 / g, (item.group + 1) as f64 / g);
                    min_coverage(&coverage) >= k
                }
                RecoveryRule::Global { k } => {
                    done_ids.insert(item.group);
                    done_ids.len() >= k
                }
            };
            if recovered {
                return Ok(TraceOutcome {
                    computation_time: t,
                    decode_time,
                    transition_waste: waste,
                    reallocations,
                    completions,
                });
            }
            workers[who].pointer += 1;
            schedule_next(
                scheme, &alloc, &mut workers[who], who, job, cost, speeds, &coverage,
                &done_ids, t,
            );
        } else {
            // Apply the batch of elastic events at this timestamp.
            t = next_event_t;
            let before_alloc = alloc.clone();
            let before_active = active.clone();
            let before_pointers: Vec<usize> = workers.iter().map(|w| w.pointer).collect();
            while ev_idx < trace.events.len()
                && (trace.events[ev_idx].time - t).abs() < 1e-12
            {
                match trace.events[ev_idx].kind {
                    EventKind::Leave(s) => active.retain(|&x| x != s),
                    EventKind::Join(s) => {
                        active.push(s);
                        active.sort_unstable();
                    }
                }
                ev_idx += 1;
            }
            if active.is_empty() {
                return Err(SimError::Unrecoverable { at: t, reason: "no active workers".into() });
            }
            if active.len() < scheme.min_workers() {
                return Err(SimError::Unrecoverable {
                    at: t,
                    reason: format!(
                        "{} active workers < scheme minimum {}",
                        active.len(),
                        scheme.min_workers()
                    ),
                });
            }
            alloc = scheme.allocate_active(&active);
            // Transition waste over surviving workers (plus fresh joiners).
            let survivors: Vec<(usize, Option<(usize, usize)>)> = active
                .iter()
                .enumerate()
                .map(|(w_new, &slot)| {
                    match before_active.iter().position(|&s| s == slot) {
                        Some(w_old) => (w_new, Some((w_old, before_pointers[w_old]))),
                        None => (w_new, None),
                    }
                })
                .collect();
            if reassign == Reassign::MaxOverlap
                && matches!(alloc.rule, RecoveryRule::PerSet { .. })
            {
                let assignment = crate::tas::reassign::max_overlap_assignment(
                    &before_alloc,
                    &alloc,
                    &survivors,
                );
                alloc = crate::tas::reassign::apply_assignment(&alloc, &assignment);
            }
            waste += transition::total_waste(&before_alloc, &alloc, &survivors);
            if matches!(alloc.rule, RecoveryRule::PerSet { .. }) {
                reallocations += 1;
            }
            workers = init_workers(
                scheme, &alloc, &active, job, cost, speeds, &coverage, &done_ids, t,
            );
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn init_workers(
    scheme: &dyn Scheme,
    alloc: &Allocation,
    active: &[usize],
    job: JobSpec,
    cost: &CostModel,
    speeds: &WorkerSpeeds,
    coverage: &[IntervalSet],
    done_ids: &HashSet<usize>,
    now: f64,
) -> Vec<WorkerState> {
    active
        .iter()
        .enumerate()
        .map(|(w, &slot)| {
            let mut st = WorkerState { slot, pointer: 0, next_done: f64::INFINITY };
            schedule_next(scheme, alloc, &mut st, w, job, cost, speeds, coverage, done_ids, now);
            st
        })
        .collect()
}

/// Advance `st` past already-covered items and set `next_done` for the
/// first item with real work left (or INFINITY when exhausted).
#[allow(clippy::too_many_arguments)]
fn schedule_next(
    scheme: &dyn Scheme,
    alloc: &Allocation,
    st: &mut WorkerState,
    w: usize,
    job: JobSpec,
    cost: &CostModel,
    speeds: &WorkerSpeeds,
    coverage: &[IntervalSet],
    done_ids: &HashSet<usize>,
    now: f64,
) -> bool {
    let list = &alloc.lists[w];
    let mult = speeds.multiplier(st.slot);
    let n = alloc.workers();
    loop {
        if st.pointer >= list.len() {
            st.next_done = f64::INFINITY;
            return false;
        }
        let item = list[st.pointer];
        match alloc.rule {
            RecoveryRule::PerSet { sets, .. } => {
                let g = sets as f64;
                let (lo, hi) = (item.group as f64 / g, (item.group + 1) as f64 / g);
                let uncovered = coverage[st.slot].uncovered_in(lo, hi);
                if uncovered < 1e-12 {
                    st.pointer += 1; // nothing left to compute; skip free
                    continue;
                }
                // ops for the uncovered fraction of the whole encoded task:
                // subtask_ops covers 1/g of the task.
                let ops = scheme.subtask_ops(job.u, job.w, job.v, n) as f64 * uncovered * g;
                st.next_done = now + cost.worker_time(ops.round() as u64, mult);
                return true;
            }
            RecoveryRule::Global { .. } => {
                if done_ids.contains(&item.group) {
                    st.pointer += 1;
                    continue;
                }
                let ops = scheme.subtask_ops(job.u, job.w, job.v, n);
                st.next_done = now + cost.worker_time(ops, mult);
                return true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::default_rng;
    use crate::sim::{SpeedModel, WorkerSpeeds};
    use crate::tas::{Bicec, Cec, Mlcec};

    fn cm() -> CostModel {
        CostModel::paper_default()
    }

    fn job() -> JobSpec {
        JobSpec::new(240, 240, 240)
    }

    #[test]
    fn static_trace_matches_static_simulator() {
        let scheme = Cec::new(2, 4);
        let speeds = WorkerSpeeds::uniform(8);
        let trace = ElasticTrace::static_n(8, 8);
        let out = simulate_trace(&scheme, &trace, job(), &cm(), &speeds).unwrap();
        let st = crate::sim::simulate_static(&scheme, 8, job(), &cm(), &speeds);
        assert!((out.computation_time - st.computation_time).abs() < 1e-9);
        assert_eq!(out.reallocations, 0);
        assert_eq!(out.transition_waste, 0.0);
    }

    #[test]
    fn bicec_zero_waste_under_fig1_trace() {
        let scheme = Bicec::new(600, 300, 8);
        let speeds = WorkerSpeeds::uniform(8);
        // Events early enough to interrupt the run.
        let ops = scheme.subtask_ops(240, 240, 240, 8);
        let tau = cm().worker_time(ops, 1.0);
        let trace = ElasticTrace::fig1(10.0 * tau, 20.0 * tau);
        let out = simulate_trace(&scheme, &trace, job(), &cm(), &speeds).unwrap();
        assert_eq!(out.transition_waste, 0.0);
        assert_eq!(out.reallocations, 0);
    }

    #[test]
    fn cec_pays_waste_under_fig1_trace() {
        let scheme = Cec::new(2, 4);
        let speeds = WorkerSpeeds::uniform(8);
        let ops = scheme.subtask_ops(240, 240, 240, 8);
        let tau = cm().worker_time(ops, 1.0);
        // First event after one subtask each (run still far from done).
        let trace = ElasticTrace::fig1(1.5 * tau, 1.9 * tau);
        let out = simulate_trace(&scheme, &trace, job(), &cm(), &speeds).unwrap();
        assert!(out.transition_waste > 0.0);
        assert_eq!(out.reallocations, 2);
    }

    #[test]
    fn preemption_slows_completion() {
        let scheme = Bicec::new(600, 300, 8);
        let speeds = WorkerSpeeds::uniform(8);
        let ops = scheme.subtask_ops(240, 240, 240, 8);
        let tau = cm().worker_time(ops, 1.0);
        let quiet = ElasticTrace::static_n(8, 8);
        let stormy = ElasticTrace::fig1(5.0 * tau, 10.0 * tau);
        let a = simulate_trace(&scheme, &quiet, job(), &cm(), &speeds).unwrap();
        let b = simulate_trace(&scheme, &stormy, job(), &cm(), &speeds).unwrap();
        assert!(b.computation_time > a.computation_time);
    }

    #[test]
    fn join_event_helps() {
        let scheme = Bicec::new(600, 300, 8);
        let speeds = WorkerSpeeds::uniform(8);
        let ops = scheme.subtask_ops(240, 240, 240, 8);
        let tau = cm().worker_time(ops, 1.0);
        let mut with_join = ElasticTrace::static_n(8, 4);
        with_join.events.push(ElasticEvent { time: 5.0 * tau, kind: EventKind::Join(4) });
        with_join.events.push(ElasticEvent { time: 5.0 * tau, kind: EventKind::Join(5) });
        let without = ElasticTrace::static_n(8, 4);
        let a = simulate_trace(&scheme, &with_join, job(), &cm(), &speeds).unwrap();
        let b = simulate_trace(&scheme, &without, job(), &cm(), &speeds).unwrap();
        assert!(a.computation_time < b.computation_time);
    }

    use super::super::trace::ElasticEvent;

    #[test]
    fn work_retained_across_reallocation() {
        // A CEC run with an event must not take longer than completely
        // restarting at the event time plus the pre-event elapsed time
        // (retention can only help).
        let scheme = Cec::new(2, 4);
        let speeds = WorkerSpeeds::uniform(8);
        let ops = scheme.subtask_ops(240, 240, 240, 8);
        let tau = cm().worker_time(ops, 1.0);
        let trace = ElasticTrace::fig1(1.5 * tau, 1000.0 * tau);
        let out = simulate_trace(&scheme, &trace, job(), &cm(), &speeds).unwrap();
        // Restart-from-zero bound: 1.5 tau elapsed + full static run at N=6.
        let fresh6 = crate::sim::simulate_static(&scheme, 6, job(), &cm(), &speeds);
        assert!(out.computation_time <= 1.5 * tau + fresh6.computation_time + 1e-9);
    }

    #[test]
    fn unrecoverable_when_everyone_leaves_early() {
        let scheme = Cec::new(2, 4);
        let speeds = WorkerSpeeds::uniform(4);
        let trace = ElasticTrace {
            n_max: 4,
            n_initial: 4,
            events: (0..4)
                .map(|s| ElasticEvent { time: 1e-9, kind: EventKind::Leave(s) })
                .collect(),
        };
        match simulate_trace(&scheme, &trace, job(), &cm(), &speeds) {
            Err(SimError::Unrecoverable { .. }) => {}
            other => panic!("expected Unrecoverable, got {other:?}"),
        }
    }

    #[test]
    fn stragglers_with_elasticity_all_schemes_finish() {
        let mut rng = default_rng(11);
        let speeds = WorkerSpeeds::sample(&SpeedModel::paper_default(), 8, &mut rng);
        let trace = ElasticTrace::poisson(8, 4, 8, 0.05, 1e6, &mut rng);
        let schemes: Vec<Box<dyn Scheme>> = vec![
            Box::new(Cec::new(2, 4)),
            Box::new(Mlcec::new(2, 4)),
            Box::new(Bicec::new(600, 300, 8)),
        ];
        for s in &schemes {
            let out = simulate_trace(s.as_ref(), &trace, job(), &cm(), &speeds);
            assert!(out.is_ok(), "{} failed: {:?}", s.name(), out.err());
        }
    }
}

#[cfg(test)]
mod reassign_tests {
    use super::*;
    use crate::sim::{CostModel, WorkerSpeeds};
    use crate::tas::Cec;
    use crate::workload::JobSpec;

    #[test]
    fn max_overlap_never_increases_waste_or_time() {
        let scheme = Cec::new(2, 4);
        let job = JobSpec::new(240, 240, 240);
        let cost = CostModel::paper_default();
        let speeds = WorkerSpeeds::uniform(8);
        let ops = scheme.subtask_ops(240, 240, 240, 8);
        let tau = cost.worker_time(ops, 1.0);
        let trace = ElasticTrace::fig1(1.5 * tau, 2.7 * tau);
        let naive =
            simulate_trace_with(&scheme, &trace, job, &cost, &speeds, Reassign::Identity)
                .unwrap();
        let opt =
            simulate_trace_with(&scheme, &trace, job, &cost, &speeds, Reassign::MaxOverlap)
                .unwrap();
        assert!(opt.transition_waste <= naive.transition_waste + 1e-9,
            "waste {} > {}", opt.transition_waste, naive.transition_waste);
        assert!(opt.computation_time <= naive.computation_time + 1e-9,
            "time {} > {}", opt.computation_time, naive.computation_time);
    }
}
