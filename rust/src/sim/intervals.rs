//! Interval-set bookkeeping over the row space [0, 1) of one encoded task.
//!
//! The elastic simulator tracks, per code slot, which rows of that slot's
//! encoded task have been computed. Because the product is row-separable
//! (`(Â B)[r] = Â[r] B`), a point `x` of the output row space is recoverable
//! once `K` distinct slots have covered `x` — regardless of the subtask
//! granularity that produced the coverage. That makes work retention across
//! re-subdivision exact.

/// Sorted, disjoint, half-open [lo, hi) intervals within [0, 1].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IntervalSet {
    ivs: Vec<(f64, f64)>,
}

impl IntervalSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn intervals(&self) -> &[(f64, f64)] {
        &self.ivs
    }

    /// Insert [lo, hi), merging overlaps/adjacency. Returns the measure of
    /// [lo, hi) that was *newly* covered by this insert (0 when the range
    /// was already fully covered) — the elastic simulator accumulates this
    /// into a running total so the recovery check has a cheap O(1) gate.
    ///
    /// In-place merge: no allocation beyond occasional `Vec` growth, unlike
    /// the previous rebuild-into-a-fresh-`Vec` implementation (this runs
    /// once per completed subtask in the DES hot loop).
    pub fn insert(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo <= hi, "bad interval [{lo}, {hi})");
        if lo == hi {
            return 0.0;
        }
        // Intervals strictly left of the merge window.
        let mut start = 0;
        while start < self.ivs.len() && self.ivs[start].1 < lo - 1e-12 {
            start += 1;
        }
        // Intervals touching the merge window [lo - eps, hi + eps].
        let mut end = start;
        let (mut new_lo, mut new_hi) = (lo, hi);
        let mut overlap = 0.0;
        while end < self.ivs.len() && self.ivs[end].0 <= hi + 1e-12 {
            let (a, b) = self.ivs[end];
            overlap += (b.min(hi) - a.max(lo)).max(0.0);
            new_lo = new_lo.min(a);
            new_hi = new_hi.max(b);
            end += 1;
        }
        if start == end {
            self.ivs.insert(start, (new_lo, new_hi));
        } else {
            self.ivs[start] = (new_lo, new_hi);
            self.ivs.drain(start + 1..end);
        }
        ((hi - lo) - overlap).max(0.0)
    }

    pub fn measure(&self) -> f64 {
        self.ivs.iter().map(|&(a, b)| b - a).sum()
    }

    /// Measure of [lo, hi) not yet covered.
    pub fn uncovered_in(&self, lo: f64, hi: f64) -> f64 {
        let mut rem = hi - lo;
        for &(a, b) in &self.ivs {
            let o = (b.min(hi) - a.max(lo)).max(0.0);
            rem -= o;
        }
        rem.max(0.0)
    }

    /// Is [lo, hi) fully covered (up to fp slack)?
    pub fn covers(&self, lo: f64, hi: f64) -> bool {
        self.uncovered_in(lo, hi) < 1e-9
    }

    pub fn is_empty(&self) -> bool {
        self.ivs.is_empty()
    }

    /// Drop all intervals, keeping the allocation (trial-reuse hot path).
    pub fn clear(&mut self) {
        self.ivs.clear();
    }
}

/// Minimum coverage multiplicity over [0, 1): how many of the given sets
/// cover the least-covered point. Recovery for a (·, K) MDS code over row
/// blocks requires `min_coverage(...) >= K`.
pub fn min_coverage(sets: &[IntervalSet]) -> usize {
    min_coverage_with(sets, &mut Vec::new())
}

/// `min_coverage` with a caller-owned scratch buffer for the endpoint
/// sweep, so the per-completion recovery check in the elastic simulator
/// allocates nothing in steady state.
pub fn min_coverage_with(sets: &[IntervalSet], deltas: &mut Vec<(f64, i32)>) -> usize {
    // Endpoint sweep with +1/-1 deltas.
    deltas.clear();
    for s in sets {
        for &(a, b) in s.intervals() {
            deltas.push((a.max(0.0), 1));
            deltas.push((b.min(1.0), -1));
        }
    }
    deltas.push((0.0, 0));
    deltas.push((1.0, 0));
    deltas.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
    let mut depth = 0i32;
    let mut min_depth = i32::MAX;
    let mut prev = 0.0f64;
    for &(x, d) in deltas.iter() {
        if x > prev + 1e-12 && prev < 1.0 {
            min_depth = min_depth.min(depth);
        }
        depth += d;
        prev = prev.max(x.min(1.0));
        if prev >= 1.0 {
            break;
        }
    }
    if prev < 1.0 {
        min_depth = min_depth.min(0);
    }
    if min_depth == i32::MAX {
        0
    } else {
        min_depth.max(0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn insert_merges_overlaps() {
        let mut s = IntervalSet::new();
        s.insert(0.0, 0.25);
        s.insert(0.5, 0.75);
        s.insert(0.2, 0.6);
        assert_eq!(s.intervals().len(), 1);
        assert!((s.measure() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn insert_adjacent_coalesces() {
        let mut s = IntervalSet::new();
        s.insert(0.0, 0.5);
        s.insert(0.5, 1.0);
        assert_eq!(s.intervals().len(), 1);
        assert!(s.covers(0.0, 1.0));
    }

    #[test]
    fn uncovered_in_partial() {
        let mut s = IntervalSet::new();
        s.insert(0.25, 0.5);
        assert!((s.uncovered_in(0.0, 1.0) - 0.75).abs() < 1e-12);
        assert!((s.uncovered_in(0.25, 0.5)).abs() < 1e-12);
        assert!((s.uncovered_in(0.4, 0.6) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn min_coverage_empty_and_full() {
        assert_eq!(min_coverage(&[]), 0);
        let mut full = IntervalSet::new();
        full.insert(0.0, 1.0);
        assert_eq!(min_coverage(&[full.clone()]), 1);
        assert_eq!(min_coverage(&[full.clone(), full.clone()]), 2);
    }

    #[test]
    fn min_coverage_detects_gap() {
        let mut a = IntervalSet::new();
        a.insert(0.0, 0.5);
        let mut b = IntervalSet::new();
        b.insert(0.5, 1.0);
        // Every point covered once, no point twice.
        assert_eq!(min_coverage(&[a.clone(), b.clone()]), 1);
        // Leave a hole at [0.4, 0.5): coverage drops to 0.
        let mut c = IntervalSet::new();
        c.insert(0.0, 0.4);
        assert_eq!(min_coverage(&[c, b]), 0);
    }

    #[test]
    fn insert_returns_newly_covered_measure() {
        let mut s = IntervalSet::new();
        assert!((s.insert(0.2, 0.6) - 0.4).abs() < 1e-12);
        // Fully inside existing coverage: nothing new.
        assert!(s.insert(0.3, 0.5).abs() < 1e-12);
        // Half overlapping: only the uncovered half counts.
        assert!((s.insert(0.5, 0.8) - 0.2).abs() < 1e-12);
        // Degenerate insert.
        assert_eq!(s.insert(0.1, 0.1), 0.0);
    }

    #[test]
    fn insert_measure_accounting_adjacent_contained_bridging() {
        // Dyadic endpoints: every arithmetic step below is exact in f64,
        // so the returned measures can be compared with `==`.
        let mut s = IntervalSet::new();
        assert_eq!(s.insert(0.25, 0.5), 0.25);
        // Exactly adjacent on the right: coalesces, counts only new span.
        assert_eq!(s.insert(0.5, 0.625), 0.125);
        assert_eq!(s.intervals().len(), 1);
        // Exactly adjacent on the left.
        assert_eq!(s.insert(0.125, 0.25), 0.125);
        assert_eq!(s.intervals().len(), 1);
        // Fully contained: zero new measure, no structural change.
        assert_eq!(s.insert(0.25, 0.5), 0.0);
        assert_eq!(s.intervals(), &[(0.125, 0.625)]);
        // Disjoint island.
        assert_eq!(s.insert(0.75, 0.875), 0.125);
        assert_eq!(s.intervals().len(), 2);
        // Bridge across both intervals and the gaps between them.
        assert_eq!(s.insert(0.0, 1.0), 1.0 - 0.5 - 0.125);
        assert_eq!(s.intervals(), &[(0.0, 1.0)]);
        assert_eq!(s.measure(), 1.0);
    }

    /// Recompute-from-scratch oracle: merged measure of a raw interval
    /// list via sort + sweep, independent of `IntervalSet`'s bookkeeping.
    fn merged_measure(ivs: &[(f64, f64)]) -> f64 {
        let mut sorted = ivs.to_vec();
        sorted.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
        let (mut total, mut open) = (0.0f64, None::<(f64, f64)>);
        for &(lo, hi) in &sorted {
            match open {
                Some((s, e)) if lo <= e => open = Some((s, e.max(hi))),
                Some((s, e)) => {
                    total += e - s;
                    open = Some((lo, hi));
                }
                None => open = Some((lo, hi)),
            }
        }
        if let Some((s, e)) = open {
            total += e - s;
        }
        total
    }

    #[test]
    fn prop_insert_running_measure_matches_oracle() {
        // The elastic simulator's covered-measure gate accumulates the
        // per-insert returns; any drift vs the true merged measure would
        // silently skip (or force) recovery sweeps. Grid-aligned endpoints
        // force exact adjacency, containment, and multi-interval bridging;
        // occasional off-grid inserts exercise the epsilon paths.
        prop::check(80, |g| {
            const GRID: usize = 32;
            let mut s = IntervalSet::new();
            let mut inserted: Vec<(f64, f64)> = Vec::new();
            let mut running = 0.0f64;
            for _ in 0..g.usize_in(1, 40) {
                let (lo, hi) = if g.u64() % 8 == 0 {
                    let lo = g.f64_in(0.0, 1.0);
                    (lo, lo + g.f64_in(0.0, 1.0 - lo))
                } else {
                    let a = g.usize_in(0, GRID - 1);
                    let b = g.usize_in(a + 1, GRID);
                    (a as f64 / GRID as f64, b as f64 / GRID as f64)
                };
                running += s.insert(lo, hi);
                inserted.push((lo, hi));
                let oracle = merged_measure(&inserted);
                if (running - oracle).abs() > 1e-9 {
                    return Err(format!(
                        "running sum {running} != oracle {oracle} after {inserted:?}"
                    ));
                }
                if (s.measure() - oracle).abs() > 1e-9 {
                    return Err(format!(
                        "measure {} != oracle {oracle} after {inserted:?}",
                        s.measure()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_insert_return_sums_to_measure() {
        prop::check(60, |g| {
            let mut s = IntervalSet::new();
            let mut acc = 0.0;
            for _ in 0..g.usize_in(1, 25) {
                let lo = g.f64_in(0.0, 1.0);
                let hi = lo + g.f64_in(0.0, 1.0 - lo);
                acc += s.insert(lo, hi);
            }
            if (acc - s.measure()).abs() > 1e-9 {
                return Err(format!("sum of inserts {acc} != measure {}", s.measure()));
            }
            Ok(())
        });
    }

    #[test]
    fn min_coverage_with_reuses_dirty_scratch() {
        let mut a = IntervalSet::new();
        a.insert(0.0, 1.0);
        let mut b = IntervalSet::new();
        b.insert(0.0, 0.5);
        let sets = [a, b];
        let mut scratch = vec![(99.0, 7); 32]; // deliberately dirty
        assert_eq!(min_coverage_with(&sets, &mut scratch), min_coverage(&sets));
        assert_eq!(min_coverage_with(&sets, &mut scratch), 1);
    }

    #[test]
    fn prop_insert_keeps_invariants() {
        prop::check(80, |g| {
            let mut s = IntervalSet::new();
            for _ in 0..g.usize_in(1, 30) {
                let lo = g.f64_in(0.0, 1.0);
                let hi = lo + g.f64_in(0.0, 1.0 - lo);
                s.insert(lo, hi);
                // disjoint + sorted
                for w in s.intervals().windows(2) {
                    if w[0].1 > w[1].0 + 1e-12 {
                        return Err(format!("overlap after insert: {:?}", s.intervals()));
                    }
                }
                let m = s.measure();
                if !(0.0..=1.0 + 1e-9).contains(&m) {
                    return Err(format!("measure {m} out of range"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_min_coverage_matches_pointwise_probe() {
        prop::check(40, |g| {
            let nsets = g.usize_in(1, 5);
            let sets: Vec<IntervalSet> = (0..nsets)
                .map(|_| {
                    let mut s = IntervalSet::new();
                    for _ in 0..g.usize_in(0, 4) {
                        let lo = g.f64_in(0.0, 1.0);
                        let hi = lo + g.f64_in(0.0, 1.0 - lo);
                        s.insert(lo, hi);
                    }
                    s
                })
                .collect();
            let fast = min_coverage(&sets);
            // Probe at midpoints of a fine grid.
            let probes = 400;
            let mut slow = usize::MAX;
            for i in 0..probes {
                let x = (i as f64 + 0.5) / probes as f64;
                let depth = sets
                    .iter()
                    .filter(|s| s.intervals().iter().any(|&(a, b)| a <= x && x < b))
                    .count();
                slow = slow.min(depth);
            }
            // Grid probing can miss measure-tiny gaps; fast <= slow always.
            if fast > slow {
                return Err(format!("fast {fast} > probed {slow}"));
            }
            Ok(())
        });
    }
}
