//! The calibrated cost model converting operation counts to seconds.
//!
//! Two rates (DESIGN.md §Substitutions): workers run BLAS-like matmuls
//! (`worker_ops_per_sec`); the master's decode is one big
//! inverse-times-stack combine (`decode_ops_per_sec`), also BLAS-shaped and
//! somewhat faster per op than the fine-grained worker subtasks. The paper
//! does not report rates; the ratio `rho = worker/decode ≈ 0.3` is
//! calibrated in EXPERIMENTS.md §Calibration to reproduce the paper's
//! headline numbers (BICEC −45% finishing vs CEC in Fig. 2c, MLCEC winning
//! Fig. 2d for N ≥ 32) and can be re-measured on this machine with
//! `CostModel::calibrate()`.

use std::time::Instant;

use crate::linalg::{gemm, Matrix};
use crate::rng::default_rng;

#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Multiply-adds per second of a fast (non-straggler) worker.
    pub worker_ops_per_sec: f64,
    /// Multiply-adds per second of the master's decode combine.
    pub decode_ops_per_sec: f64,
}

impl CostModel {
    /// Fixed rates used by the figure benches: reproducible across
    /// machines, ratio calibrated to the paper (rho ≈ 0.3).
    pub fn paper_default() -> Self {
        Self { worker_ops_per_sec: 3.0e9, decode_ops_per_sec: 1.0e10 }
    }

    /// Measure this machine: worker rate from a blocked f32 gemm, decode
    /// rate from the axpy-combine pattern the decoder actually runs.
    pub fn calibrate() -> Self {
        let mut rng = default_rng(0xCA11B);
        // Worker rate: 256^3 gemm.
        let a = Matrix::random(256, 256, &mut rng);
        let b = Matrix::random(256, 256, &mut rng);
        let t0 = Instant::now();
        let reps = 4;
        for _ in 0..reps {
            std::hint::black_box(gemm(&a, &b));
        }
        let worker = (reps * 256usize.pow(3)) as f64 / t0.elapsed().as_secs_f64();

        // Decode rate: k-way axpy combine into a large block.
        let k = 10;
        let blocks: Vec<Matrix> =
            (0..k).map(|_| Matrix::random(64, 4096, &mut rng)).collect();
        let t1 = Instant::now();
        let reps = 8;
        for _ in 0..reps {
            let mut acc = Matrix::zeros(64, 4096);
            for (i, blk) in blocks.iter().enumerate() {
                acc.axpy(0.1 + i as f32, blk);
            }
            std::hint::black_box(acc);
        }
        let decode = (reps * k * 64 * 4096) as f64 / t1.elapsed().as_secs_f64();
        Self { worker_ops_per_sec: worker, decode_ops_per_sec: decode }
    }

    /// Seconds for a worker with speed `multiplier` to run `ops`
    /// multiply-adds.
    #[inline]
    pub fn worker_time(&self, ops: u64, multiplier: f64) -> f64 {
        ops as f64 * multiplier / self.worker_ops_per_sec
    }

    /// Seconds for the master to decode `ops` multiply-adds.
    #[inline]
    pub fn decode_time(&self, ops: u64) -> f64 {
        ops as f64 / self.decode_ops_per_sec
    }

    pub fn rho(&self) -> f64 {
        self.worker_ops_per_sec / self.decode_ops_per_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_ratio() {
        let cm = CostModel::paper_default();
        assert!((cm.rho() - 0.3).abs() < 0.01);
    }

    #[test]
    fn worker_time_scales_with_multiplier() {
        let cm = CostModel::paper_default();
        let fast = cm.worker_time(1_000_000, 1.0);
        let slow = cm.worker_time(1_000_000, 10.0);
        assert!((slow / fast - 10.0).abs() < 1e-9);
    }

    #[test]
    fn decode_time_linear_in_ops() {
        let cm = CostModel::paper_default();
        assert!((cm.decode_time(2_000) / cm.decode_time(1_000) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn calibrate_produces_sane_rates() {
        let cm = CostModel::calibrate();
        // Any machine this runs on does >= 10 Mops/s in both paths and the
        // worker path is the faster one in ops/s terms... not guaranteed,
        // but both must be positive and finite.
        assert!(cm.worker_ops_per_sec > 1e7, "{}", cm.worker_ops_per_sec);
        assert!(cm.decode_ops_per_sec > 1e6, "{}", cm.decode_ops_per_sec);
    }
}
