//! Static-N discrete-event run — the paper's Sec. 3 experiment.
//!
//! Workers process their to-do lists sequentially; the master needs `K`
//! completions per set (CEC/MLCEC) or `K` overall (BICEC). With fixed
//! speeds the completion time of worker `w`'s `j`-th item is
//! `(j+1) · subtask_time(w)`, so set completion times are order statistics —
//! no event queue needed.

use crate::tas::{Allocation, RecoveryRule, Scheme};
use crate::workload::JobSpec;

use super::{CostModel, WorkerSpeeds};

/// Outcome of one simulated run.
#[derive(Clone, Copy, Debug)]
pub struct RunResult {
    /// Time until the recovery rule is satisfied (computation phase).
    pub computation_time: f64,
    /// Master decode time (cost model).
    pub decode_time: f64,
    /// Subtask completions consumed by recovery (including redundant ones
    /// finished before the last needed one).
    pub completions_used: u64,
    /// Total subtask completions that would finish by `computation_time`
    /// across all workers — `completions_used` plus overshoot.
    pub completions_total: u64,
}

impl RunResult {
    pub fn finishing_time(&self) -> f64 {
        self.computation_time + self.decode_time
    }
}

/// Simulate one static run of `scheme` with `n` available workers
/// (slots `0..n` active).
pub fn simulate_static(
    scheme: &dyn Scheme,
    n: usize,
    job: JobSpec,
    cost: &CostModel,
    speeds: &WorkerSpeeds,
) -> RunResult {
    assert!(speeds.n_max() >= n, "need speeds for {n} slots");
    let alloc = scheme.allocate(n);
    let ops = scheme.subtask_ops(job.u, job.w, job.v, n);
    let comp = computation_time(&alloc, |w| cost.worker_time(ops, speeds.multiplier(w)));
    let decode = cost.decode_time(scheme.decode_ops(job.u, job.v));
    let mut total = 0u64;
    for (w, list) in alloc.lists.iter().enumerate() {
        let tau = cost.worker_time(ops, speeds.multiplier(w));
        let done = ((comp / tau).floor() as usize).min(list.len());
        total += done as u64;
    }
    // completions consumed: K per set, or K overall.
    let used = match alloc.rule {
        RecoveryRule::PerSet { sets, k } => (sets * k) as u64,
        RecoveryRule::Global { k } => k as u64,
    };
    RunResult { computation_time: comp, decode_time: decode, completions_used: used, completions_total: total }
}

/// Time until the recovery rule of `alloc` is met, given each worker's
/// per-subtask duration `tau(w)`.
pub fn computation_time(alloc: &Allocation, tau: impl Fn(usize) -> f64) -> f64 {
    match alloc.rule {
        RecoveryRule::PerSet { sets, k } => {
            // completion of set m = k-th smallest over holders' item times.
            let mut set_times: Vec<Vec<f64>> = vec![Vec::new(); sets];
            for (w, list) in alloc.lists.iter().enumerate() {
                let t = tau(w);
                for (pos, item) in list.iter().enumerate() {
                    set_times[item.group].push((pos + 1) as f64 * t);
                }
            }
            let mut worst = 0.0f64;
            for (m, times) in set_times.iter_mut().enumerate() {
                assert!(
                    times.len() >= k,
                    "set {m} has only {} holders < K={k}",
                    times.len()
                );
                // k-th order statistic via selection (O(d) vs O(d log d)
                // sort) — this is the figure harness's hot loop (§Perf).
                let (_, kth, _) = times
                    .select_nth_unstable_by(k - 1, |a, b| a.partial_cmp(b).unwrap());
                worst = worst.max(*kth);
            }
            worst
        }
        RecoveryRule::Global { k } => {
            let mut events: Vec<f64> = Vec::new();
            for (w, list) in alloc.lists.iter().enumerate() {
                let t = tau(w);
                for pos in 0..list.len() {
                    events.push((pos + 1) as f64 * t);
                }
            }
            assert!(events.len() >= k, "only {} events < K={k}", events.len());
            let (_, kth, _) =
                events.select_nth_unstable_by(k - 1, |a, b| a.partial_cmp(b).unwrap());
            *kth
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::default_rng;
    use crate::sim::SpeedModel;
    use crate::tas::{Bicec, Cec, Mlcec};

    fn cm() -> CostModel {
        CostModel::paper_default()
    }

    #[test]
    fn uniform_speeds_cec_closed_form() {
        // All workers equal, ascending processing: the binding set is the
        // last one, which every holder reaches at position S, so the run
        // completes at S * tau (the paper's "wasteful" alignment).
        let scheme = Cec::new(2, 4);
        let job = JobSpec::new(240, 240, 240);
        let speeds = WorkerSpeeds::uniform(8);
        let r = simulate_static(&scheme, 8, job, &cm(), &speeds);
        let ops = scheme.subtask_ops(240, 240, 240, 8);
        let tau = cm().worker_time(ops, 1.0);
        assert!((r.computation_time - 4.0 * tau).abs() < 1e-12);
    }

    #[test]
    fn uniform_speeds_bicec_closed_form() {
        // n workers advance in lockstep: after j rounds, n*j completions;
        // K=600 with n=8 -> ceil(600/8) = 75 rounds.
        let scheme = Bicec::new(600, 300, 8);
        let job = JobSpec::new(240, 240, 240);
        let speeds = WorkerSpeeds::uniform(8);
        let r = simulate_static(&scheme, 8, job, &cm(), &speeds);
        let ops = scheme.subtask_ops(240, 240, 240, 8);
        let tau = cm().worker_time(ops, 1.0);
        assert!((r.computation_time - 75.0 * tau).abs() < 1e-9);
    }

    #[test]
    fn mlcec_beats_cec_on_average_with_stragglers() {
        // The paper's claim is about the straggler-prone average: MLCEC's
        // hierarchical d-levels equalise set completion. (Under *uniform*
        // speeds CEC's perfect staggering is optimal and MLCEC is slower —
        // the gain exists only because stragglers exist.)
        let job = JobSpec::paper_square();
        let mut rng = default_rng(100);
        let trials = 30;
        let (mut sum_cec, mut sum_mlcec) = (0.0, 0.0);
        for _ in 0..trials {
            let speeds = WorkerSpeeds::sample(&SpeedModel::paper_default(), 40, &mut rng);
            sum_cec += simulate_static(&Cec::new(10, 20), 40, job, &cm(), &speeds)
                .computation_time;
            sum_mlcec += simulate_static(&Mlcec::new(10, 20), 40, job, &cm(), &speeds)
                .computation_time;
        }
        assert!(
            sum_mlcec < sum_cec,
            "MLCEC avg {} must beat CEC avg {}",
            sum_mlcec / trials as f64,
            sum_cec / trials as f64
        );
    }

    #[test]
    fn bicec_computation_lower_bounds_others_with_stragglers() {
        // Paper Sec. 3: BICEC's continuous completion is a lower bound.
        let job = JobSpec::paper_square();
        let mut rng = default_rng(7);
        let speeds = WorkerSpeeds::sample(&SpeedModel::paper_default(), 40, &mut rng);
        let cec = simulate_static(&Cec::new(10, 20), 40, job, &cm(), &speeds);
        let mlcec = simulate_static(&Mlcec::new(10, 20), 40, job, &cm(), &speeds);
        let bicec = simulate_static(&Bicec::new(800, 80, 40), 40, job, &cm(), &speeds);
        assert!(bicec.computation_time <= mlcec.computation_time);
        assert!(bicec.computation_time <= cec.computation_time);
    }

    #[test]
    fn decode_time_ordering_matches_paper() {
        // Fig 2b: BICEC decode >> CEC = MLCEC decode.
        let job = JobSpec::paper_square();
        let speeds = WorkerSpeeds::uniform(40);
        let cec = simulate_static(&Cec::new(10, 20), 40, job, &cm(), &speeds);
        let bicec = simulate_static(&Bicec::new(800, 80, 40), 40, job, &cm(), &speeds);
        assert!(bicec.decode_time > 10.0 * cec.decode_time);
    }

    #[test]
    fn slower_workers_slow_the_run() {
        let scheme = Cec::new(2, 4);
        let job = JobSpec::new(240, 240, 240);
        let fast = simulate_static(&scheme, 8, job, &cm(), &WorkerSpeeds::uniform(8));
        let slow = simulate_static(
            &scheme,
            8,
            job,
            &cm(),
            &WorkerSpeeds::from_vec(vec![3.0; 8]),
        );
        assert!((slow.computation_time / fast.computation_time - 3.0).abs() < 1e-9);
    }

    #[test]
    fn completions_total_at_least_used() {
        let job = JobSpec::paper_square();
        let mut rng = default_rng(9);
        let speeds = WorkerSpeeds::sample(&SpeedModel::paper_default(), 40, &mut rng);
        for scheme in [&Cec::new(10, 20) as &dyn Scheme, &Bicec::new(800, 80, 40)] {
            let r = simulate_static(scheme, 40, job, &cm(), &speeds);
            assert!(r.completions_total >= r.completions_used / 2,
                "recovery counts should be plausible: {r:?}");
            assert!(r.finishing_time() > r.computation_time);
        }
    }
}
