//! Static-N discrete-event run — the paper's Sec. 3 experiment.
//!
//! Workers process their to-do lists sequentially; the master needs `K`
//! completions per set (CEC/MLCEC) or `K` overall (BICEC). With fixed
//! speeds the completion time of worker `w`'s `j`-th item is
//! `(j+1) · subtask_time(w)`, so set completion times are order statistics —
//! no event queue needed.
//!
//! Hot-path structure (EXPERIMENTS.md §Perf): every per-run allocation is
//! hoisted into [`SimScratch`]; the Global (BICEC) order statistic is found
//! by bisecting the f64 bit lattice against an O(N) counting function
//! instead of materialising all `N·S` event times; the PerSet (CEC/MLCEC)
//! max-of-k-th uses the same bisection, gated behind a counting pass so
//! only binding sets pay for it; and [`StaticSimulator`] /
//! [`simulate_many`] amortise the scheme's `allocate(n)` across
//! Monte-Carlo trials and fan the trials out across a worker pool
//! (bit-identical to serial — see `crate::threads` for the budget).

use crate::tas::{Allocation, RecoveryRule, Scheme};
use crate::workload::JobSpec;

use super::{CostModel, WorkerSpeeds};

/// Outcome of one simulated run.
#[derive(Clone, Copy, Debug)]
pub struct RunResult {
    /// Time until the recovery rule is satisfied (computation phase).
    pub computation_time: f64,
    /// Master decode time (cost model).
    pub decode_time: f64,
    /// Subtask completions consumed by recovery (including redundant ones
    /// finished before the last needed one).
    pub completions_used: u64,
    /// Total subtask completions that would finish by `computation_time`
    /// across all workers — `completions_used` plus overshoot.
    pub completions_total: u64,
}

impl RunResult {
    pub fn finishing_time(&self) -> f64 {
        self.computation_time + self.decode_time
    }
}

/// Reusable buffers for the order-statistics fast path. One instance per
/// simulator (or per thread); `Default` starts empty and every buffer grows
/// to its high-water mark, after which runs allocate nothing.
#[derive(Debug, Default)]
pub struct SimScratch {
    /// Per-worker subtask duration for the current run.
    taus: Vec<f64>,
    /// Per-worker list length (Global rule).
    lens: Vec<usize>,
    /// PerSet: holders per set.
    counts: Vec<usize>,
    /// PerSet: prefix offsets into `times` (len = sets + 1).
    offsets: Vec<usize>,
    /// PerSet: write cursor per set during the scatter pass.
    cursor: Vec<usize>,
    /// PerSet: flat per-set completion-time buckets.
    times: Vec<f64>,
}

/// Count events `(j+1) · taus[w] <= t` for `j < lens[w]`, exactly on the
/// f64 multiplication lattice (the same expression the event times are
/// generated from, so no epsilon is involved).
fn count_events_at(lens: &[usize], taus: &[f64], t: f64) -> u64 {
    let mut count = 0u64;
    for (&len, &tau) in lens.iter().zip(taus) {
        if len == 0 {
            continue;
        }
        if tau <= 0.0 {
            // Degenerate: every event at time 0.
            if t >= 0.0 {
                count += len as u64;
            }
            continue;
        }
        let mut q = ((t / tau).floor() as i64).clamp(0, len as i64);
        // Repair fp division drift against the multiplication lattice.
        while q < len as i64 && ((q + 1) as f64) * tau <= t {
            q += 1;
        }
        while q > 0 && (q as f64) * tau > t {
            q -= 1;
        }
        count += q as u64;
    }
    count
}

/// Smallest non-negative f64 `t` with `count(t) >= k`, by bisection on the
/// f64 bit lattice (non-negative finite f64s are ordered like their bit
/// patterns). `count` must be monotone with `count(hi) >= k`. Exact: since
/// `count` only steps at event times, the result IS the k-th event time.
/// Shared by the Global (BICEC) order statistic and the PerSet binding-set
/// selection below.
fn bisect_event_time(hi: f64, k: u64, count: impl Fn(f64) -> u64) -> f64 {
    if count(0.0) >= k {
        return 0.0;
    }
    debug_assert!(count(hi) >= k, "bisection bracket must contain the answer");
    let mut lo_bits = 0u64;
    let mut hi_bits = hi.to_bits();
    while lo_bits + 1 < hi_bits {
        let mid = lo_bits + (hi_bits - lo_bits) / 2;
        if count(f64::from_bits(mid)) >= k {
            hi_bits = mid;
        } else {
            lo_bits = mid;
        }
    }
    f64::from_bits(hi_bits)
}

/// k-th smallest event time over all workers' arithmetic event sequences,
/// via the bit-lattice bisection: O(N · 64) instead of materialising and
/// selecting over N·S event times.
fn kth_event_time(lens: &[usize], taus: &[f64], k: usize) -> f64 {
    let total: u64 = lens.iter().map(|&l| l as u64).sum();
    assert!(total >= k as u64, "only {total} events < K={k}");
    let mut hi = 0.0f64;
    for (&len, &tau) in lens.iter().zip(taus) {
        hi = hi.max(len as f64 * tau.max(0.0));
    }
    bisect_event_time(hi, k as u64, |t| count_events_at(lens, taus, t))
}

/// k-th smallest of `xs` (k >= 1, counted from the minimum) over
/// non-negative finite values, via the same bit-lattice bisection as the
/// Global path. Exact: returns the k-th order statistic itself.
fn kth_smallest(xs: &[f64], k: usize) -> f64 {
    debug_assert!(k >= 1 && k <= xs.len());
    let mut hi = 0.0f64;
    for &x in xs {
        hi = hi.max(x);
    }
    bisect_event_time(hi, k as u64, |t| xs.iter().filter(|&&x| x <= t).count() as u64)
}

/// Time until the recovery rule of `alloc` is met, given each worker's
/// per-subtask duration `tau(w)`.
pub fn computation_time(alloc: &Allocation, tau: impl Fn(usize) -> f64) -> f64 {
    computation_time_with(alloc, tau, &mut SimScratch::default())
}

/// `computation_time` against caller-owned scratch (the figure harness's
/// hot loop — §Perf).
pub fn computation_time_with(
    alloc: &Allocation,
    tau: impl Fn(usize) -> f64,
    scratch: &mut SimScratch,
) -> f64 {
    let n_workers = alloc.lists.len();
    scratch.taus.clear();
    scratch.taus.extend((0..n_workers).map(&tau));
    match alloc.rule {
        RecoveryRule::PerSet { sets, k } => {
            // Bucket the per-set completion times into one flat buffer:
            // count, prefix, scatter, then the gated max-of-kth sweep.
            scratch.counts.clear();
            scratch.counts.resize(sets, 0);
            for list in &alloc.lists {
                for item in list {
                    scratch.counts[item.group] += 1;
                }
            }
            scratch.offsets.clear();
            scratch.offsets.reserve(sets + 1);
            let mut acc = 0usize;
            scratch.offsets.push(0);
            for &c in &scratch.counts {
                acc += c;
                scratch.offsets.push(acc);
            }
            scratch.cursor.clear();
            scratch.cursor.extend_from_slice(&scratch.offsets[..sets]);
            scratch.times.clear();
            scratch.times.resize(acc, 0.0);
            for (w, list) in alloc.lists.iter().enumerate() {
                let t = scratch.taus[w];
                for (pos, item) in list.iter().enumerate() {
                    let at = scratch.cursor[item.group];
                    scratch.times[at] = (pos + 1) as f64 * t;
                    scratch.cursor[item.group] += 1;
                }
            }
            // Max of per-set k-th order statistics. A set whose first k
            // completions all land by the running max cannot move it, and
            // that test is one branchless counting pass over d values —
            // the same count-vs-threshold predicate the Global rule
            // bisects on. Only the few *binding* sets pay the exact
            // bit-lattice bisection; this replaces the old
            // `select_nth_unstable_by` (with its swaps and per-element
            // `partial_cmp`) on every set (§Perf).
            let mut worst = 0.0f64;
            for m in 0..sets {
                let seg = &scratch.times[scratch.offsets[m]..scratch.offsets[m + 1]];
                assert!(
                    seg.len() >= k,
                    "set {m} has only {} holders < K={k}",
                    seg.len()
                );
                let done_by_worst = seg.iter().filter(|&&x| x <= worst).count();
                if done_by_worst >= k {
                    continue;
                }
                worst = kth_smallest(seg, k);
            }
            worst
        }
        RecoveryRule::Global { k } => {
            scratch.lens.clear();
            scratch.lens.extend(alloc.lists.iter().map(|l| l.len()));
            kth_event_time(&scratch.lens, &scratch.taus, k)
        }
    }
}

/// Reusable static-run driver: caches the allocation per (scheme, n, job)
/// and owns the scratch, so Monte-Carlo sweeps pay `allocate(n)` and the
/// buffer allocations once instead of per trial.
pub struct StaticSimulator<'a> {
    scheme: &'a dyn Scheme,
    /// (n, job, allocation, subtask ops) of the last-used geometry.
    cached: Option<(usize, JobSpec, Allocation, u64)>,
    scratch: SimScratch,
}

impl<'a> StaticSimulator<'a> {
    pub fn new(scheme: &'a dyn Scheme) -> Self {
        Self { scheme, cached: None, scratch: SimScratch::default() }
    }

    /// Simulate one static run of the scheme with `n` available workers.
    pub fn run(
        &mut self,
        n: usize,
        job: JobSpec,
        cost: &CostModel,
        speeds: &WorkerSpeeds,
    ) -> RunResult {
        assert!(speeds.n_max() >= n, "need speeds for {n} slots");
        let rebuild = match &self.cached {
            Some((cn, cjob, _, _)) => *cn != n || *cjob != job,
            None => true,
        };
        if rebuild {
            let alloc = self.scheme.allocate(n);
            let ops = self.scheme.subtask_ops(job.u, job.w, job.v, n);
            self.cached = Some((n, job, alloc, ops));
        }
        let (_, _, alloc, ops) = self.cached.as_ref().expect("cached above");
        let (alloc, ops) = (alloc, *ops);
        let comp = computation_time_with(
            alloc,
            |w| cost.worker_time(ops, speeds.multiplier(w)),
            &mut self.scratch,
        );
        let decode = cost.decode_time(self.scheme.decode_ops(job.u, job.v));
        let mut total = 0u64;
        for (w, list) in alloc.lists.iter().enumerate() {
            let tau = cost.worker_time(ops, speeds.multiplier(w));
            let done = ((comp / tau).floor() as usize).min(list.len());
            total += done as u64;
        }
        // completions consumed: K per set, or K overall.
        let used = match alloc.rule {
            RecoveryRule::PerSet { sets, k } => (sets * k) as u64,
            RecoveryRule::Global { k } => k as u64,
        };
        RunResult {
            computation_time: comp,
            decode_time: decode,
            completions_used: used,
            completions_total: total,
        }
    }
}

/// Simulate one static run of `scheme` with `n` available workers
/// (slots `0..n` active).
pub fn simulate_static(
    scheme: &dyn Scheme,
    n: usize,
    job: JobSpec,
    cost: &CostModel,
    speeds: &WorkerSpeeds,
) -> RunResult {
    StaticSimulator::new(scheme).run(n, job, cost, speeds)
}

/// Batch driver: one run per entry of `speeds_per_trial`, amortising the
/// allocation and scratch across the whole Monte-Carlo sweep and fanning
/// the trials out across a `std::thread::scope` worker pool (one
/// `StaticSimulator` per worker, no steady-state allocation inside the
/// trial loop).
///
/// Bit-identical to the serial driver for any thread count: each trial is
/// a pure function of `(scheme, n, job, cost, speeds)` and lands in its
/// own output slot by index. Thread budget comes from `crate::threads`
/// (`HCEC_THREADS`, nested-region guard).
pub fn simulate_many(
    scheme: &dyn Scheme,
    n: usize,
    job: JobSpec,
    cost: &CostModel,
    speeds_per_trial: &[WorkerSpeeds],
) -> Vec<RunResult> {
    let threads = crate::threads::plan_units(speeds_per_trial.len());
    simulate_many_threaded(scheme, n, job, cost, speeds_per_trial, threads)
}

/// [`simulate_many`] with an explicit thread request (still clamped by the
/// shared budget — `crate::threads::plan`). Results are identical for any
/// count; the scenario engine's `threads` knob lands here.
pub fn simulate_many_with_threads(
    scheme: &dyn Scheme,
    n: usize,
    job: JobSpec,
    cost: &CostModel,
    speeds_per_trial: &[WorkerSpeeds],
    threads: usize,
) -> Vec<RunResult> {
    let threads = crate::threads::plan(threads);
    simulate_many_threaded(scheme, n, job, cost, speeds_per_trial, threads)
}

/// `simulate_many` with an explicit worker count (1 = run on the caller).
fn simulate_many_threaded(
    scheme: &dyn Scheme,
    n: usize,
    job: JobSpec,
    cost: &CostModel,
    speeds_per_trial: &[WorkerSpeeds],
    threads: usize,
) -> Vec<RunResult> {
    let zero = RunResult {
        computation_time: 0.0,
        decode_time: 0.0,
        completions_used: 0,
        completions_total: 0,
    };
    let mut out = vec![zero; speeds_per_trial.len()];
    // Contiguous chunks: trial i lands in out[i] regardless of the worker
    // count, so the fan-out is invisible in the results.
    crate::threads::scatter_chunks(&mut out, threads, |start, slots| {
        let mut sim = StaticSimulator::new(scheme);
        for (off, slot) in slots.iter_mut().enumerate() {
            *slot = sim.run(n, job, cost, &speeds_per_trial[start + off]);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::default_rng;
    use crate::sim::SpeedModel;
    use crate::tas::{Bicec, Cec, Mlcec};

    fn cm() -> CostModel {
        CostModel::paper_default()
    }

    #[test]
    fn uniform_speeds_cec_closed_form() {
        // All workers equal, ascending processing: the binding set is the
        // last one, which every holder reaches at position S, so the run
        // completes at S * tau (the paper's "wasteful" alignment).
        let scheme = Cec::new(2, 4);
        let job = JobSpec::new(240, 240, 240);
        let speeds = WorkerSpeeds::uniform(8);
        let r = simulate_static(&scheme, 8, job, &cm(), &speeds);
        let ops = scheme.subtask_ops(240, 240, 240, 8);
        let tau = cm().worker_time(ops, 1.0);
        assert!((r.computation_time - 4.0 * tau).abs() < 1e-12);
    }

    #[test]
    fn uniform_speeds_bicec_closed_form() {
        // n workers advance in lockstep: after j rounds, n*j completions;
        // K=600 with n=8 -> ceil(600/8) = 75 rounds.
        let scheme = Bicec::new(600, 300, 8);
        let job = JobSpec::new(240, 240, 240);
        let speeds = WorkerSpeeds::uniform(8);
        let r = simulate_static(&scheme, 8, job, &cm(), &speeds);
        let ops = scheme.subtask_ops(240, 240, 240, 8);
        let tau = cm().worker_time(ops, 1.0);
        assert!((r.computation_time - 75.0 * tau).abs() < 1e-9);
    }

    #[test]
    fn kth_event_time_matches_materialised_selection() {
        // Cross-check the bit-lattice bisection against the sort-everything
        // reference on irregular speeds and list lengths.
        let mut rng = default_rng(40);
        for trial in 0..50 {
            let n = 1 + (trial % 7);
            let lens: Vec<usize> = (0..n).map(|_| (rng.next_u64() % 9) as usize).collect();
            let taus: Vec<f64> = (0..n)
                .map(|_| 0.25 + (rng.next_u64() % 1000) as f64 / 250.0)
                .collect();
            let mut events: Vec<f64> = Vec::new();
            for (&len, &tau) in lens.iter().zip(&taus) {
                for pos in 0..len {
                    events.push((pos + 1) as f64 * tau);
                }
            }
            if events.is_empty() {
                continue;
            }
            events.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for k in [1, events.len() / 2 + 1, events.len()] {
                let fast = kth_event_time(&lens, &taus, k);
                let want = events[k - 1];
                assert_eq!(fast, want, "trial {trial} k={k}: {fast} vs {want}");
            }
        }
    }

    #[test]
    fn kth_smallest_matches_sorted_reference() {
        let mut rng = default_rng(42);
        for trial in 0..60 {
            let len = 1 + (trial % 9);
            let xs: Vec<f64> = (0..len)
                .map(|_| (rng.next_u64() % 4000) as f64 / 128.0)
                .collect();
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for k in 1..=len {
                assert_eq!(
                    kth_smallest(&xs, k),
                    sorted[k - 1],
                    "trial {trial} k={k} xs={xs:?}"
                );
            }
        }
    }

    #[test]
    fn perset_max_of_kth_matches_selection_reference() {
        // The gated bisection must reproduce the old select-per-set result
        // bit for bit, on irregular speeds across CEC geometries.
        let mut rng = default_rng(55);
        for trial in 0..40 {
            let s = 2 + (trial % 5);
            let k = 1 + trial % s;
            let scheme = Cec::new(k, s);
            let n = s + (trial % 7);
            let alloc = scheme.allocate(n);
            let taus: Vec<f64> = (0..n)
                .map(|_| 0.25 + (rng.next_u64() % 1000) as f64 / 300.0)
                .collect();
            let fast = computation_time(&alloc, |w| taus[w]);
            let RecoveryRule::PerSet { sets, k } = alloc.rule else {
                panic!("CEC is PerSet")
            };
            let mut worst = 0.0f64;
            for m in 0..sets {
                let mut times: Vec<f64> = alloc
                    .lists
                    .iter()
                    .enumerate()
                    .filter_map(|(w, list)| {
                        list.iter()
                            .position(|it| it.group == m)
                            .map(|p| (p + 1) as f64 * taus[w])
                    })
                    .collect();
                times.sort_by(|a, b| a.partial_cmp(b).unwrap());
                worst = worst.max(times[k - 1]);
            }
            assert_eq!(fast, worst, "trial {trial} (n={n}, s={s}, k={k})");
        }
    }

    #[test]
    fn parallel_simulate_many_bit_identical_to_serial() {
        // The acceptance bar: every per-trial result equal, not just the
        // means. Exercised on both recovery rules.
        let job = JobSpec::paper_square();
        let mut rng = default_rng(808);
        let speeds: Vec<WorkerSpeeds> = (0..33)
            .map(|_| WorkerSpeeds::sample(&SpeedModel::paper_default(), 40, &mut rng))
            .collect();
        let schemes = [
            &Cec::new(10, 20) as &dyn Scheme,
            &Mlcec::new(10, 20),
            &Bicec::new(800, 80, 40),
        ];
        for scheme in schemes {
            let serial = simulate_many_threaded(scheme, 40, job, &cm(), &speeds, 1);
            for threads in [2, 4, 7] {
                let parallel =
                    simulate_many_threaded(scheme, 40, job, &cm(), &speeds, threads);
                assert_eq!(serial.len(), parallel.len());
                for (i, (a, b)) in serial.iter().zip(&parallel).enumerate() {
                    assert_eq!(
                        a.computation_time, b.computation_time,
                        "trial {i} at {threads} threads"
                    );
                    assert_eq!(a.decode_time, b.decode_time, "trial {i}");
                    assert_eq!(a.completions_used, b.completions_used, "trial {i}");
                    assert_eq!(a.completions_total, b.completions_total, "trial {i}");
                }
            }
        }
    }

    #[test]
    fn simulate_many_matches_one_off_runs() {
        let scheme = Bicec::new(800, 80, 40);
        let job = JobSpec::paper_square();
        let mut rng = default_rng(41);
        let speeds: Vec<WorkerSpeeds> = (0..8)
            .map(|_| WorkerSpeeds::sample(&SpeedModel::paper_default(), 40, &mut rng))
            .collect();
        let batch = simulate_many(&scheme, 40, job, &cm(), &speeds);
        assert_eq!(batch.len(), 8);
        for (i, sp) in speeds.iter().enumerate() {
            let single = simulate_static(&scheme, 40, job, &cm(), sp);
            assert_eq!(
                batch[i].computation_time, single.computation_time,
                "trial {i} diverged"
            );
            assert_eq!(batch[i].completions_total, single.completions_total);
        }
    }

    #[test]
    fn static_simulator_reuse_across_n_and_job() {
        // Geometry changes must invalidate the cached allocation.
        let scheme = Cec::new(2, 4);
        let speeds = WorkerSpeeds::uniform(10);
        let mut sim = StaticSimulator::new(&scheme);
        let a = sim.run(8, JobSpec::new(240, 240, 240), &cm(), &speeds);
        let b = sim.run(10, JobSpec::new(240, 240, 240), &cm(), &speeds);
        let c = sim.run(8, JobSpec::new(480, 240, 240), &cm(), &speeds);
        let a2 = sim.run(8, JobSpec::new(240, 240, 240), &cm(), &speeds);
        assert_eq!(a.computation_time, a2.computation_time);
        assert_ne!(a.computation_time, b.computation_time);
        assert_ne!(a.computation_time, c.computation_time);
    }

    #[test]
    fn mlcec_beats_cec_on_average_with_stragglers() {
        // The paper's claim is about the straggler-prone average: MLCEC's
        // hierarchical d-levels equalise set completion. (Under *uniform*
        // speeds CEC's perfect staggering is optimal and MLCEC is slower —
        // the gain exists only because stragglers exist.)
        let job = JobSpec::paper_square();
        let mut rng = default_rng(100);
        let trials = 30;
        let (mut sum_cec, mut sum_mlcec) = (0.0, 0.0);
        for _ in 0..trials {
            let speeds = WorkerSpeeds::sample(&SpeedModel::paper_default(), 40, &mut rng);
            sum_cec += simulate_static(&Cec::new(10, 20), 40, job, &cm(), &speeds)
                .computation_time;
            sum_mlcec += simulate_static(&Mlcec::new(10, 20), 40, job, &cm(), &speeds)
                .computation_time;
        }
        assert!(
            sum_mlcec < sum_cec,
            "MLCEC avg {} must beat CEC avg {}",
            sum_mlcec / trials as f64,
            sum_cec / trials as f64
        );
    }

    #[test]
    fn bicec_computation_lower_bounds_others_with_stragglers() {
        // Paper Sec. 3: BICEC's continuous completion is a lower bound.
        let job = JobSpec::paper_square();
        let mut rng = default_rng(7);
        let speeds = WorkerSpeeds::sample(&SpeedModel::paper_default(), 40, &mut rng);
        let cec = simulate_static(&Cec::new(10, 20), 40, job, &cm(), &speeds);
        let mlcec = simulate_static(&Mlcec::new(10, 20), 40, job, &cm(), &speeds);
        let bicec = simulate_static(&Bicec::new(800, 80, 40), 40, job, &cm(), &speeds);
        assert!(bicec.computation_time <= mlcec.computation_time);
        assert!(bicec.computation_time <= cec.computation_time);
    }

    #[test]
    fn decode_time_ordering_matches_paper() {
        // Fig 2b: BICEC decode >> CEC = MLCEC decode.
        let job = JobSpec::paper_square();
        let speeds = WorkerSpeeds::uniform(40);
        let cec = simulate_static(&Cec::new(10, 20), 40, job, &cm(), &speeds);
        let bicec = simulate_static(&Bicec::new(800, 80, 40), 40, job, &cm(), &speeds);
        assert!(bicec.decode_time > 10.0 * cec.decode_time);
    }

    #[test]
    fn slower_workers_slow_the_run() {
        let scheme = Cec::new(2, 4);
        let job = JobSpec::new(240, 240, 240);
        let fast = simulate_static(&scheme, 8, job, &cm(), &WorkerSpeeds::uniform(8));
        let slow = simulate_static(
            &scheme,
            8,
            job,
            &cm(),
            &WorkerSpeeds::from_vec(vec![3.0; 8]),
        );
        assert!((slow.computation_time / fast.computation_time - 3.0).abs() < 1e-9);
    }

    #[test]
    fn completions_total_at_least_used() {
        let job = JobSpec::paper_square();
        let mut rng = default_rng(9);
        let speeds = WorkerSpeeds::sample(&SpeedModel::paper_default(), 40, &mut rng);
        for scheme in [&Cec::new(10, 20) as &dyn Scheme, &Bicec::new(800, 80, 40)] {
            let r = simulate_static(scheme, 40, job, &cm(), &speeds);
            assert!(r.completions_total >= r.completions_used / 2,
                "recovery counts should be plausible: {r:?}");
            assert!(r.finishing_time() > r.computation_time);
        }
    }
}
