//! Elastic traces: timed join/leave events over worker slots.
//!
//! The paper's target platforms (EC2 Spot, Azure Batch) preempt and grant
//! nodes with short notice; we model this as a marked point process within
//! `[n_min, n_max]` and as replayable trace files (one event per line:
//! `<time> leave|join <slot>`).

use crate::rng::{Exponential, Rng};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Slot is preempted (short notice: takes effect at `time`).
    Leave(usize),
    /// Slot becomes available again.
    Join(usize),
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ElasticEvent {
    pub time: f64,
    pub kind: EventKind,
}

/// A validated event sequence starting from slots `0..n_initial` active.
#[derive(Clone, Debug, Default)]
pub struct ElasticTrace {
    pub n_max: usize,
    pub n_initial: usize,
    pub events: Vec<ElasticEvent>,
}

impl ElasticTrace {
    /// Empty trace: static run with `n_initial` workers.
    pub fn static_n(n_max: usize, n_initial: usize) -> Self {
        assert!(n_initial <= n_max);
        Self { n_max, n_initial, events: Vec::new() }
    }

    /// Poisson elasticity: exponential inter-event times at `rate`; each
    /// event is a leave (uniform active slot) or join (uniform inactive
    /// slot) chosen to stay inside [n_min, n_max], 50/50 when both legal.
    pub fn poisson<R: Rng>(
        n_max: usize,
        n_min: usize,
        n_initial: usize,
        rate: f64,
        horizon: f64,
        rng: &mut R,
    ) -> Self {
        assert!(n_min <= n_initial && n_initial <= n_max && n_min >= 1);
        let exp = Exponential::new(rate);
        let mut active: Vec<bool> = (0..n_max).map(|s| s < n_initial).collect();
        let mut n = n_initial;
        let mut t = 0.0;
        let mut events = Vec::new();
        loop {
            t += exp.sample(rng);
            if t >= horizon {
                break;
            }
            let can_leave = n > n_min;
            let can_join = n < n_max;
            let leave = match (can_leave, can_join) {
                (true, true) => rng.next_u64() & 1 == 0,
                (true, false) => true,
                (false, true) => false,
                (false, false) => break,
            };
            if leave {
                let actives: Vec<usize> =
                    (0..n_max).filter(|&s| active[s]).collect();
                let slot = actives[rng.next_below(actives.len() as u64) as usize];
                active[slot] = false;
                n -= 1;
                events.push(ElasticEvent { time: t, kind: EventKind::Leave(slot) });
            } else {
                let idles: Vec<usize> =
                    (0..n_max).filter(|&s| !active[s]).collect();
                let slot = idles[rng.next_below(idles.len() as u64) as usize];
                active[slot] = true;
                n += 1;
                events.push(ElasticEvent { time: t, kind: EventKind::Join(slot) });
            }
        }
        Self { n_max, n_initial, events }
    }

    /// The paper's Fig. 1 scenario: start with 8, lose two pairs.
    pub fn fig1(t1: f64, t2: f64) -> Self {
        Self {
            n_max: 8,
            n_initial: 8,
            events: vec![
                ElasticEvent { time: t1, kind: EventKind::Leave(6) },
                ElasticEvent { time: t1, kind: EventKind::Leave(7) },
                ElasticEvent { time: t2, kind: EventKind::Leave(4) },
                ElasticEvent { time: t2, kind: EventKind::Leave(5) },
            ],
        }
    }

    /// Validate ordering and slot legality; returns active count over time.
    pub fn validate(&self) -> Result<(), String> {
        let mut active: Vec<bool> = (0..self.n_max).map(|s| s < self.n_initial).collect();
        let mut prev = 0.0f64;
        for (i, ev) in self.events.iter().enumerate() {
            if ev.time < prev {
                return Err(format!("event {i} out of order ({} < {prev})", ev.time));
            }
            prev = ev.time;
            match ev.kind {
                EventKind::Leave(s) => {
                    if s >= self.n_max || !active[s] {
                        return Err(format!("event {i}: leave of inactive slot {s}"));
                    }
                    active[s] = false;
                }
                EventKind::Join(s) => {
                    if s >= self.n_max || active[s] {
                        return Err(format!("event {i}: join of active slot {s}"));
                    }
                    active[s] = true;
                }
            }
        }
        Ok(())
    }

    /// Serialise: header line `n_max n_initial`, then one event per line.
    pub fn to_text(&self) -> String {
        let mut out = format!("{} {}\n", self.n_max, self.n_initial);
        for ev in &self.events {
            let (kind, slot) = match ev.kind {
                EventKind::Leave(s) => ("leave", s),
                EventKind::Join(s) => ("join", s),
            };
            out.push_str(&format!("{} {} {}\n", ev.time, kind, slot));
        }
        out
    }

    pub fn from_text(text: &str) -> Result<Self, String> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().ok_or("empty trace")?;
        let mut hp = header.split_whitespace();
        let n_max: usize = hp
            .next()
            .ok_or("missing n_max")?
            .parse()
            .map_err(|e| format!("n_max: {e}"))?;
        let n_initial: usize = hp
            .next()
            .ok_or("missing n_initial")?
            .parse()
            .map_err(|e| format!("n_initial: {e}"))?;
        let mut events = Vec::new();
        for (ln, line) in lines.enumerate() {
            let mut parts = line.split_whitespace();
            let time: f64 = parts
                .next()
                .ok_or(format!("line {ln}: missing time"))?
                .parse()
                .map_err(|e| format!("line {ln}: {e}"))?;
            let kind = parts.next().ok_or(format!("line {ln}: missing kind"))?;
            let slot: usize = parts
                .next()
                .ok_or(format!("line {ln}: missing slot"))?
                .parse()
                .map_err(|e| format!("line {ln}: {e}"))?;
            let kind = match kind {
                "leave" => EventKind::Leave(slot),
                "join" => EventKind::Join(slot),
                other => return Err(format!("line {ln}: unknown kind {other}")),
            };
            events.push(ElasticEvent { time, kind });
        }
        let trace = Self { n_max, n_initial, events };
        trace.validate()?;
        Ok(trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::rng::default_rng;

    #[test]
    fn fig1_trace_validates() {
        let t = ElasticTrace::fig1(1.0, 2.0);
        t.validate().unwrap();
        assert_eq!(t.events.len(), 4);
    }

    #[test]
    fn poisson_trace_respects_bounds() {
        let mut rng = default_rng(4);
        let t = ElasticTrace::poisson(40, 20, 30, 0.5, 100.0, &mut rng);
        t.validate().unwrap();
        let mut n = t.n_initial as i64;
        for ev in &t.events {
            n += match ev.kind {
                EventKind::Leave(_) => -1,
                EventKind::Join(_) => 1,
            };
            assert!((20..=40).contains(&(n as usize)), "n={n}");
        }
    }

    #[test]
    fn text_round_trip() {
        let mut rng = default_rng(5);
        let t = ElasticTrace::poisson(8, 4, 8, 1.0, 20.0, &mut rng);
        let text = t.to_text();
        let back = ElasticTrace::from_text(&text).unwrap();
        assert_eq!(back.n_max, t.n_max);
        assert_eq!(back.n_initial, t.n_initial);
        assert_eq!(back.events.len(), t.events.len());
        for (a, b) in t.events.iter().zip(&back.events) {
            assert_eq!(a.kind, b.kind);
            assert!((a.time - b.time).abs() < 1e-9);
        }
    }

    #[test]
    fn validate_rejects_double_leave() {
        let t = ElasticTrace {
            n_max: 4,
            n_initial: 4,
            events: vec![
                ElasticEvent { time: 1.0, kind: EventKind::Leave(0) },
                ElasticEvent { time: 2.0, kind: EventKind::Leave(0) },
            ],
        };
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_rejects_out_of_order() {
        let t = ElasticTrace {
            n_max: 4,
            n_initial: 4,
            events: vec![
                ElasticEvent { time: 2.0, kind: EventKind::Leave(0) },
                ElasticEvent { time: 1.0, kind: EventKind::Leave(1) },
            ],
        };
        assert!(t.validate().is_err());
    }

    #[test]
    fn prop_poisson_traces_always_valid() {
        prop::check(30, |g| {
            let n_min = g.usize_in(1, 5);
            let n_max = n_min + g.usize_in(0, 10);
            let n_init = g.usize_in(n_min, n_max);
            let mut rng = g.rng().clone();
            let t = ElasticTrace::poisson(n_max, n_min, n_init, 1.0, 50.0, &mut rng);
            t.validate().map_err(|e| e)?;
            Ok(())
        });
    }
}
