//! Simulation substrate: worker speed models, the calibrated cost model,
//! the static discrete-event run used by the figures, and the elastic-trace
//! simulator with exact cross-granularity work retention.
//!
//! Two modes (DESIGN.md §Substitutions):
//!
//! * **static** (`statics`) — fixed `N` for the whole run, as in the
//!   paper's Sec. 3 experiments (the x-axis of Fig. 2 sweeps N; no mid-run
//!   elasticity). Order-statistics fast path.
//! * **trace** (`elastic`) — workers join/leave mid-run per an
//!   `ElasticTrace`. Completed work is tracked as row-intervals of each
//!   worker's encoded task, so re-subdivision at a new granularity retains
//!   exactly the rows already computed (the products are row-separable).

pub mod cost;
pub mod elastic;
pub mod intervals;
pub mod statics;
pub mod straggler;
pub mod trace;

pub use cost::CostModel;
pub use elastic::{
    simulate_trace, simulate_trace_with, Reassign, TraceMonteCarlo, TraceOutcome,
    TraceSimulator,
};
pub use statics::{
    simulate_many, simulate_many_with_threads, simulate_static, RunResult, SimScratch,
    StaticSimulator,
};
pub use straggler::{SpeedModel, WorkerSpeeds};
pub use trace::{ElasticEvent, ElasticTrace, EventKind};
