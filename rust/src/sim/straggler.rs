//! Worker speed models.
//!
//! The paper's experiment: "each available worker becomes straggler with
//! probability 0.5". The slowdown factor is not reported; we default to
//! 10x (calibrated in EXPERIMENTS.md §Calibration to reproduce the paper's
//! relative curves) and sweep {2, 5, 10} in the Ext-T3 ablation. A small
//! log-normal jitter breaks the deterministic ties a two-point speed
//! distribution would otherwise produce.

use crate::rng::{Bernoulli, Exponential, LogNormal, Rng};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SpeedModel {
    /// Paper model: straggle w.p. `p`, stragglers are `slowdown`x slower;
    /// every worker gets a log-normal(0, `jitter`) multiplicative jitter.
    BernoulliSlowdown { p: f64, slowdown: f64, jitter: f64 },
    /// Shifted exponential (Lee et al. 2018): multiplier = 1 + Exp(rate).
    ShiftedExponential { rate: f64 },
}

impl SpeedModel {
    /// The paper's configuration with our calibrated defaults.
    pub fn paper_default() -> Self {
        SpeedModel::BernoulliSlowdown { p: 0.5, slowdown: 10.0, jitter: 0.05 }
    }

    /// Sample one worker's time-per-op multiplier (>= 1 means slower).
    pub fn sample<R: Rng>(&self, rng: &mut R) -> f64 {
        match *self {
            SpeedModel::BernoulliSlowdown { p, slowdown, jitter } => {
                let base = if Bernoulli::new(p).sample(rng) { slowdown } else { 1.0 };
                base * LogNormal::new(0.0, jitter).sample(rng)
            }
            SpeedModel::ShiftedExponential { rate } => {
                1.0 + Exponential::new(rate).sample(rng)
            }
        }
    }
}

/// Per-slot speed multipliers for one trial. Indexed by *slot id* (the code
/// row the worker stores), not by position in the active list, so elastic
/// re-joins keep their speed.
#[derive(Clone, Debug)]
pub struct WorkerSpeeds {
    multipliers: Vec<f64>,
}

impl WorkerSpeeds {
    pub fn sample<R: Rng>(model: &SpeedModel, n_max: usize, rng: &mut R) -> Self {
        Self { multipliers: (0..n_max).map(|_| model.sample(rng)).collect() }
    }

    pub fn uniform(n_max: usize) -> Self {
        Self { multipliers: vec![1.0; n_max] }
    }

    pub fn from_vec(multipliers: Vec<f64>) -> Self {
        assert!(multipliers.iter().all(|&m| m > 0.0));
        Self { multipliers }
    }

    pub fn n_max(&self) -> usize {
        self.multipliers.len()
    }

    #[inline]
    pub fn multiplier(&self, slot: usize) -> f64 {
        self.multipliers[slot]
    }

    pub fn stragglers(&self, threshold: f64) -> usize {
        self.multipliers.iter().filter(|&&m| m >= threshold).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::default_rng;

    #[test]
    fn bernoulli_model_two_modes() {
        let mut rng = default_rng(1);
        let model = SpeedModel::BernoulliSlowdown { p: 0.5, slowdown: 10.0, jitter: 0.0 };
        let speeds = WorkerSpeeds::sample(&model, 10_000, &mut rng);
        let slow = speeds.stragglers(5.0);
        // ~half the workers straggle
        assert!((4_500..5_500).contains(&slow), "slow={slow}");
        for slot in 0..speeds.n_max() {
            let m = speeds.multiplier(slot);
            assert!((m - 1.0).abs() < 1e-9 || (m - 10.0).abs() < 1e-9);
        }
    }

    #[test]
    fn jitter_separates_equal_speeds() {
        let mut rng = default_rng(2);
        let speeds = WorkerSpeeds::sample(&SpeedModel::paper_default(), 40, &mut rng);
        let mut ms: Vec<f64> = (0..40).map(|s| speeds.multiplier(s)).collect();
        ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ms.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        assert_eq!(ms.len(), 40, "jitter must break ties");
    }

    #[test]
    fn shifted_exponential_at_least_one() {
        let mut rng = default_rng(3);
        let model = SpeedModel::ShiftedExponential { rate: 0.5 };
        for _ in 0..1_000 {
            assert!(model.sample(&mut rng) >= 1.0);
        }
    }

    #[test]
    fn speeds_indexed_by_slot_stable() {
        let speeds = WorkerSpeeds::from_vec(vec![1.0, 10.0, 2.5]);
        assert_eq!(speeds.multiplier(1), 10.0);
        assert_eq!(speeds.n_max(), 3);
    }
}
