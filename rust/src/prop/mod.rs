//! Property-testing mini-framework.
//!
//! `proptest` is not in the vendored crate set, so this module provides the
//! subset the test suite needs: seeded generators, a case runner that
//! reports the failing seed, and a greedy input shrinker for integer-vector
//! cases. Usage:
//!
//! ```ignore
//! prop::check(200, |g| {
//!     let n = g.usize_in(1, 64);
//!     let xs = g.vec_f64(n, -1e3, 1e3);
//!     // ... assert invariant, or return Err(reason)
//!     Ok(())
//! });
//! ```

use crate::rng::{default_rng, Rng, Xoshiro256pp};

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Xoshiro256pp,
    /// Seed that produced this case, for reproduction messages.
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: default_rng(seed), seed }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + self.rng.next_below((hi - lo + 1) as u64) as i64
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, n: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..n).map(|_| self.usize_in(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.next_below(xs.len() as u64) as usize]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        self.rng.shuffle(xs);
    }

    pub fn rng(&mut self) -> &mut Xoshiro256pp {
        &mut self.rng
    }
}

/// Run `cases` random cases of `property`. Panics with the reproducing seed
/// on the first failure. The base seed is fixed so CI is deterministic;
/// override with env `HCEC_PROP_SEED` to explore.
pub fn check<F>(cases: u64, property: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base = std::env::var("HCEC_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0DEDC0FFEEu64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut gen = Gen::new(seed);
        if let Err(msg) = property(&mut gen) {
            panic!(
                "property failed (case {case}, seed {seed:#x}): {msg}\n\
                 reproduce with HCEC_PROP_SEED={base} and case index {case}"
            );
        }
    }
}

/// Greedy shrinker for counterexamples expressed as an integer vector:
/// repeatedly tries removing elements and halving values while the failure
/// persists. Returns the smallest failing input found.
pub fn shrink_ints<F>(mut input: Vec<i64>, still_fails: F) -> Vec<i64>
where
    F: Fn(&[i64]) -> bool,
{
    debug_assert!(still_fails(&input));
    loop {
        let mut changed = false;
        // Try dropping each element.
        let mut i = 0;
        while i < input.len() {
            let mut cand = input.clone();
            cand.remove(i);
            if still_fails(&cand) {
                input = cand;
                changed = true;
            } else {
                i += 1;
            }
        }
        // Try halving each element toward zero.
        for i in 0..input.len() {
            while input[i] != 0 {
                let mut cand = input.clone();
                cand[i] /= 2;
                if cand != input && still_fails(&cand) {
                    input = cand;
                    changed = true;
                } else {
                    break;
                }
            }
        }
        if !changed {
            return input;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(50, |g| {
            let n = g.usize_in(0, 100);
            if n <= 100 {
                Ok(())
            } else {
                Err(format!("{n} > 100"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failures() {
        check(50, |g| {
            let n = g.usize_in(0, 100);
            if n < 90 {
                Ok(())
            } else {
                Err("n too big".into())
            }
        });
    }

    #[test]
    fn gen_ranges_inclusive() {
        let mut g = Gen::new(3);
        for _ in 0..1000 {
            let v = g.usize_in(5, 7);
            assert!((5..=7).contains(&v));
            let w = g.i64_in(-3, 3);
            assert!((-3..=3).contains(&w));
        }
    }

    #[test]
    fn shrinker_reaches_minimal_example() {
        // Failure: vector contains any element >= 10.
        let fails = |xs: &[i64]| xs.iter().any(|&x| x >= 10);
        let shrunk = shrink_ints(vec![3, 100, 7, 42], fails);
        assert_eq!(shrunk.len(), 1);
        assert!(shrunk[0] >= 10 && shrunk[0] <= 12, "shrunk={shrunk:?}");
    }
}
