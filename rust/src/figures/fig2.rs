//! Fig. 2 — the paper's quantitative evaluation.
//!
//! (a) average computation time vs N (uwv = 2400³)
//! (b) average decoding time vs N, square and tall x fat shapes
//! (c) average finishing time vs N, square
//! (d) average finishing time vs N, tall x fat
//!
//! One trial samples one straggler draw shared by all three schemes
//! (paired comparison, like the paper's single simulated cluster), then
//! runs the static DES per scheme.
//!
//! Each grid point is one `scenario::Scenario` on the `Statics` engine
//! ([`fig2_scenario`]), seeded `cfg.seed ^ (n << 32)` with sequential
//! per-trial draws — the exact derivation of the pre-Scenario harness, so
//! fixed-seed outputs are bit-identical (asserted in
//! `tests/scenario_equivalence.rs`).

use crate::config::ExperimentConfig;
use crate::metrics::Table;
use crate::scenario::{Engine, Scenario, SchemeConfig};
use crate::workload::JobSpec;

pub use crate::scenario::Metric;

/// Mean metric per (N, scheme) over the config's trials.
pub struct Fig2Point {
    pub n: usize,
    pub cec: crate::metrics::Summary,
    pub mlcec: crate::metrics::Summary,
    pub bicec: crate::metrics::Summary,
}

/// The Fig. 2 scenario at one grid point: paper scheme trio, paired
/// straggler draws, fixed fleet of `n` active workers out of `cfg.n_max`.
pub fn fig2_scenario(cfg: &ExperimentConfig, job: JobSpec, n: usize) -> Scenario {
    Scenario::builder(&format!("fig2_n{n}"))
        .engine(Engine::Statics)
        .job(job)
        .fleet(cfg.n_max, n)
        .schemes(SchemeConfig::paper_trio(cfg))
        .speed_model(cfg.speed_model())
        .cost(cfg.cost_model())
        .trials(cfg.trials)
        .seed(cfg.seed ^ (n as u64) << 32)
        .build()
        .expect("ExperimentConfig produces a valid fig2 scenario")
}

pub fn fig2_series(cfg: &ExperimentConfig, metric: Metric, job: JobSpec) -> Vec<Fig2Point> {
    cfg.ns
        .iter()
        .map(|&n| {
            let out = fig2_scenario(cfg, job, n)
                .run()
                .expect("statics engine cannot fail on a valid scenario");
            Fig2Point {
                n,
                cec: out.per_scheme[0].summary(metric),
                mlcec: out.per_scheme[1].summary(metric),
                bicec: out.per_scheme[2].summary(metric),
            }
        })
        .collect()
}

/// Render one subfigure as the paper's series (+ relative improvements).
pub fn fig2_table(cfg: &ExperimentConfig, which: &str) -> Table {
    let (metric, job): (Metric, JobSpec) = match which {
        "2a" => (Metric::Computation, cfg.job),
        "2b" => (Metric::Decode, cfg.job),
        "2c" => (Metric::Finishing, JobSpec::paper_square()),
        "2d" => (Metric::Finishing, JobSpec::paper_tall_fat()),
        other => panic!("unknown figure {other:?} (expected 2a|2b|2c|2d)"),
    };
    let series = fig2_series(cfg, metric, job);
    let mut t = Table::new(&[
        "N",
        "cec_s",
        "mlcec_s",
        "bicec_s",
        "mlcec_vs_cec_%",
        "bicec_vs_cec_%",
    ]);
    for p in &series {
        let rel = |x: f64| 100.0 * (x - p.cec.mean) / p.cec.mean;
        t.row(vec![
            p.n.to_string(),
            format!("{:.4}", p.cec.mean),
            format!("{:.4}", p.mlcec.mean),
            format!("{:.4}", p.bicec.mean),
            format!("{:+.1}", rel(p.mlcec.mean)),
            format!("{:+.1}", rel(p.bicec.mean)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        ExperimentConfig { trials: 6, ns: vec![20, 30, 40], ..Default::default() }
    }

    #[test]
    fn fig2a_shape_bicec_best_mlcec_between() {
        let cfg = quick_cfg();
        let series = fig2_series(&cfg, Metric::Computation, cfg.job);
        for p in &series {
            assert!(p.bicec.mean < p.cec.mean, "N={}: BICEC must win computation", p.n);
            assert!(p.bicec.mean <= p.mlcec.mean, "N={}: BICEC lower-bounds MLCEC", p.n);
        }
        // Paper headline: ~85% at N=40 — accept the 70..95 band.
        let last = series.last().unwrap();
        let imp = 100.0 * (last.cec.mean - last.bicec.mean) / last.cec.mean;
        assert!((70.0..=95.0).contains(&imp), "BICEC improvement {imp:.1}% at N=40");
    }

    #[test]
    fn fig2b_shape_bicec_decode_dominates_and_grows_with_v() {
        let cfg = quick_cfg();
        let sq = fig2_series(&cfg, Metric::Decode, JobSpec::paper_square());
        let tf = fig2_series(&cfg, Metric::Decode, JobSpec::paper_tall_fat());
        for (a, b) in sq.iter().zip(&tf) {
            assert!(a.bicec.mean > 10.0 * a.cec.mean, "BICEC decode must dominate");
            assert!((a.cec.mean - a.mlcec.mean).abs() < 1e-12, "CEC == MLCEC decode");
            assert!(b.bicec.mean > a.bicec.mean, "decode grows with v");
        }
    }

    #[test]
    fn fig2c_shape_bicec_best_finishing_square() {
        let cfg = quick_cfg();
        let series = fig2_series(&cfg, Metric::Finishing, JobSpec::paper_square());
        for p in &series {
            assert!(p.bicec.mean < p.cec.mean, "N={}: BICEC wins Fig 2c", p.n);
        }
        let last = series.last().unwrap();
        let imp = 100.0 * (last.cec.mean - last.bicec.mean) / last.cec.mean;
        assert!((30.0..=60.0).contains(&imp), "Fig2c headline ~45%, got {imp:.1}%");
    }

    #[test]
    fn fig2d_shape_mlcec_wins_at_large_n() {
        let cfg = quick_cfg();
        let series = fig2_series(&cfg, Metric::Finishing, JobSpec::paper_tall_fat());
        let last = series.last().unwrap();
        assert!(
            last.mlcec.mean < last.cec.mean && last.mlcec.mean < last.bicec.mean,
            "N=40: MLCEC must win Fig 2d (cec={:.3} mlcec={:.3} bicec={:.3})",
            last.cec.mean,
            last.mlcec.mean,
            last.bicec.mean
        );
    }

    #[test]
    fn table_has_one_row_per_n() {
        let cfg = quick_cfg();
        let t = fig2_table(&cfg, "2a");
        assert_eq!(t.n_rows(), cfg.ns.len());
    }

    #[test]
    fn fig2_scenario_round_trips_through_toml() {
        let cfg = quick_cfg();
        let sc = fig2_scenario(&cfg, cfg.job, 40);
        let back = Scenario::from_toml(&sc.to_toml()).unwrap();
        assert_eq!(back.to_doc(), sc.to_doc());
    }
}
