//! Fig. 2 — the paper's quantitative evaluation.
//!
//! (a) average computation time vs N (uwv = 2400³)
//! (b) average decoding time vs N, square and tall x fat shapes
//! (c) average finishing time vs N, square
//! (d) average finishing time vs N, tall x fat
//!
//! One trial samples one straggler draw shared by all three schemes
//! (paired comparison, like the paper's single simulated cluster), then
//! runs the static DES per scheme.

use crate::config::ExperimentConfig;
use crate::metrics::{Summary, Table};
use crate::rng::default_rng;
use crate::sim::{simulate_many, WorkerSpeeds};
use crate::tas::{Bicec, Cec, Mlcec, Scheme};
use crate::workload::JobSpec;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Computation,
    Decode,
    Finishing,
}

impl Metric {
    fn of(&self, r: &crate::sim::RunResult) -> f64 {
        match self {
            Metric::Computation => r.computation_time,
            Metric::Decode => r.decode_time,
            Metric::Finishing => r.finishing_time(),
        }
    }
}

/// Mean metric per (N, scheme) over the config's trials.
pub struct Fig2Point {
    pub n: usize,
    pub cec: Summary,
    pub mlcec: Summary,
    pub bicec: Summary,
}

pub fn fig2_series(cfg: &ExperimentConfig, metric: Metric, job: JobSpec) -> Vec<Fig2Point> {
    let cost = cfg.cost_model();
    let cec = Cec::new(cfg.k_cec, cfg.s_cec);
    let mlcec = Mlcec::new(cfg.k_cec, cfg.s_cec);
    let bicec = Bicec::new(cfg.k_bicec, cfg.s_bicec, cfg.n_max);
    cfg.ns
        .iter()
        .map(|&n| {
            let mut rng = default_rng(cfg.seed ^ (n as u64) << 32);
            // One straggler draw per trial, shared across schemes (paired
            // comparison); the batch driver then amortises each scheme's
            // allocate(n) and scratch across the whole sweep.
            let speeds: Vec<WorkerSpeeds> = (0..cfg.trials)
                .map(|_| WorkerSpeeds::sample(&cfg.speed_model(), cfg.n_max, &mut rng))
                .collect();
            let mut xs = [Vec::new(), Vec::new(), Vec::new()];
            for (i, scheme) in
                [&cec as &dyn Scheme, &mlcec, &bicec].into_iter().enumerate()
            {
                xs[i] = simulate_many(scheme, n, job, &cost, &speeds)
                    .iter()
                    .map(|r| metric.of(r))
                    .collect();
            }
            Fig2Point {
                n,
                cec: Summary::of(&xs[0]),
                mlcec: Summary::of(&xs[1]),
                bicec: Summary::of(&xs[2]),
            }
        })
        .collect()
}

/// Render one subfigure as the paper's series (+ relative improvements).
pub fn fig2_table(cfg: &ExperimentConfig, which: &str) -> Table {
    let (metric, job, title_cols): (Metric, JobSpec, [&str; 2]) = match which {
        "2a" => (Metric::Computation, cfg.job, ["mlcec_vs_cec_%", "bicec_vs_cec_%"]),
        "2b" => (Metric::Decode, cfg.job, ["mlcec_vs_cec_%", "bicec_vs_cec_%"]),
        "2c" => (Metric::Finishing, JobSpec::paper_square(), ["mlcec_vs_cec_%", "bicec_vs_cec_%"]),
        "2d" => {
            (Metric::Finishing, JobSpec::paper_tall_fat(), ["mlcec_vs_cec_%", "bicec_vs_cec_%"])
        }
        other => panic!("unknown figure {other:?} (expected 2a|2b|2c|2d)"),
    };
    let job = match which {
        "2c" => JobSpec::paper_square(),
        "2d" => JobSpec::paper_tall_fat(),
        _ => job,
    };
    let series = fig2_series(cfg, metric, job);
    let mut t = Table::new(&[
        "N",
        "cec_s",
        "mlcec_s",
        "bicec_s",
        title_cols[0],
        title_cols[1],
    ]);
    for p in &series {
        let rel = |x: f64| 100.0 * (x - p.cec.mean) / p.cec.mean;
        t.row(vec![
            p.n.to_string(),
            format!("{:.4}", p.cec.mean),
            format!("{:.4}", p.mlcec.mean),
            format!("{:.4}", p.bicec.mean),
            format!("{:+.1}", rel(p.mlcec.mean)),
            format!("{:+.1}", rel(p.bicec.mean)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        ExperimentConfig { trials: 6, ns: vec![20, 30, 40], ..Default::default() }
    }

    #[test]
    fn fig2a_shape_bicec_best_mlcec_between() {
        let cfg = quick_cfg();
        let series = fig2_series(&cfg, Metric::Computation, cfg.job);
        for p in &series {
            assert!(p.bicec.mean < p.cec.mean, "N={}: BICEC must win computation", p.n);
            assert!(p.bicec.mean <= p.mlcec.mean, "N={}: BICEC lower-bounds MLCEC", p.n);
        }
        // Paper headline: ~85% at N=40 — accept the 70..95 band.
        let last = series.last().unwrap();
        let imp = 100.0 * (last.cec.mean - last.bicec.mean) / last.cec.mean;
        assert!((70.0..=95.0).contains(&imp), "BICEC improvement {imp:.1}% at N=40");
    }

    #[test]
    fn fig2b_shape_bicec_decode_dominates_and_grows_with_v() {
        let cfg = quick_cfg();
        let sq = fig2_series(&cfg, Metric::Decode, JobSpec::paper_square());
        let tf = fig2_series(&cfg, Metric::Decode, JobSpec::paper_tall_fat());
        for (a, b) in sq.iter().zip(&tf) {
            assert!(a.bicec.mean > 10.0 * a.cec.mean, "BICEC decode must dominate");
            assert!((a.cec.mean - a.mlcec.mean).abs() < 1e-12, "CEC == MLCEC decode");
            assert!(b.bicec.mean > a.bicec.mean, "decode grows with v");
        }
    }

    #[test]
    fn fig2c_shape_bicec_best_finishing_square() {
        let cfg = quick_cfg();
        let series = fig2_series(&cfg, Metric::Finishing, JobSpec::paper_square());
        for p in &series {
            assert!(p.bicec.mean < p.cec.mean, "N={}: BICEC wins Fig 2c", p.n);
        }
        let last = series.last().unwrap();
        let imp = 100.0 * (last.cec.mean - last.bicec.mean) / last.cec.mean;
        assert!((30.0..=60.0).contains(&imp), "Fig2c headline ~45%, got {imp:.1}%");
    }

    #[test]
    fn fig2d_shape_mlcec_wins_at_large_n() {
        let cfg = quick_cfg();
        let series = fig2_series(&cfg, Metric::Finishing, JobSpec::paper_tall_fat());
        let last = series.last().unwrap();
        assert!(
            last.mlcec.mean < last.cec.mean && last.mlcec.mean < last.bicec.mean,
            "N=40: MLCEC must win Fig 2d (cec={:.3} mlcec={:.3} bicec={:.3})",
            last.cec.mean,
            last.mlcec.mean,
            last.bicec.mean
        );
    }

    #[test]
    fn table_has_one_row_per_n() {
        let cfg = quick_cfg();
        let t = fig2_table(&cfg, "2a");
        assert_eq!(t.n_rows(), cfg.ns.len());
    }
}
