//! Extension ablations (DESIGN.md Ext-T1..T6) — experiments the paper
//! motivates but does not plot. Every table routes through
//! `scenario::Scenario` + `Engine::run`; the old hand-wired drivers are
//! reproduced bit-for-bit (see tests/scenario_equivalence.rs).

use crate::config::ExperimentConfig;
use crate::metrics::{mean, Table};
use crate::scenario::{
    ElasticitySpec, Engine, Metric, Scenario, SchemeConfig, SeedMode, SpeedSpec,
};
use crate::sim::Reassign;
use crate::tas::{DLevelPolicy, Mlcc};
use crate::workload::JobSpec;

/// The Ext-T1/T4 elastic scenario: Fig. 1 geometry (8 slots, floor 4),
/// ~`event_rate` Poisson events per horizon, horizon scaled to the job so
/// events land mid-run. Counter-derived trial streams → the trial pool is
/// parallel yet bit-identical to serial, and every scheme/policy sees the
/// same per-trial (speeds, trace) — the paired comparison.
fn fig1_scale_scenario(
    name: &str,
    cfg: &ExperimentConfig,
    job: JobSpec,
    event_rate: f64,
    schemes: Vec<SchemeConfig>,
    reassign: Reassign,
) -> Scenario {
    let cost = cfg.cost_model();
    let horizon = 400.0 * cost.worker_time(job.ops() / 2400, 1.0);
    Scenario::builder(name)
        .engine(Engine::Trace)
        .job(job)
        .fleet(8, 8)
        .schemes(schemes)
        .speed_model(cfg.speed_model())
        .cost(cost)
        .elasticity(ElasticitySpec::Churn {
            n_min: 4,
            n_initial: 8,
            rate: event_rate / horizon,
            horizon,
            reassign,
        })
        .trials(cfg.trials)
        .seed(cfg.seed)
        .seed_mode(SeedMode::PerTrial)
        .build()
        .expect("valid fig1-scale churn scenario")
}

/// The Fig. 1-scale scheme trio (small geometry so traces bite mid-run).
fn fig1_trio() -> Vec<SchemeConfig> {
    vec![
        SchemeConfig::Cec { k: 2, s: 4 },
        SchemeConfig::Mlcec { k: 2, s: 4, policy: DLevelPolicy::LinearRamp },
        SchemeConfig::Bicec { k: 600, s_per_worker: 300 },
    ]
}

/// Ext-T1: transition waste + finishing time under Poisson elasticity.
/// BICEC's zero-waste property is the paper's Sec. 2 claim.
pub fn transition_waste_table(cfg: &ExperimentConfig, event_rate: f64) -> Table {
    let job = JobSpec::new(240, 240, 240);
    let sc = fig1_scale_scenario(
        "ext_t1_transition_waste",
        cfg,
        job,
        event_rate,
        fig1_trio(),
        Reassign::Identity,
    );
    let out = sc.run().expect("trace engine reports failures per trial");
    let mut t = Table::new(&[
        "scheme",
        "avg_waste_taskfrac",
        "avg_reallocs",
        "avg_computation_s",
        "failures",
    ]);
    for s in &out.per_scheme {
        let reallocs: Vec<f64> =
            s.ok_trials().map(|tr| tr.reallocations as f64).collect();
        t.row(vec![
            s.scheme.clone(),
            format!("{:.4}", s.mean(Metric::TransitionWaste)),
            format!("{:.2}", mean(&reallocs)),
            format!("{:.4}", s.mean(Metric::Computation)),
            s.failures().to_string(),
        ]);
    }
    t
}

/// Ext-T2: d-level policy sensitivity for MLCEC (Fig. 2a setup). One
/// statics scenario per N — CEC plus one MLCEC entry per policy, all on
/// the same per-trial draws.
pub fn dlevel_table(cfg: &ExperimentConfig) -> Table {
    let policies: Vec<(&str, DLevelPolicy)> = vec![
        ("linear_ramp", DLevelPolicy::LinearRamp),
        (
            "equalized",
            DLevelPolicy::Equalized { p_straggle: cfg.p_straggle, slowdown: cfg.slowdown },
        ),
    ];
    let mut t = Table::new(&["N", "policy", "avg_computation_s", "vs_cec_%"]);
    for &n in &cfg.ns {
        let mut schemes = vec![SchemeConfig::cec_of(cfg)];
        for (_, policy) in &policies {
            schemes.push(SchemeConfig::Mlcec {
                k: cfg.k_cec,
                s: cfg.s_cec,
                policy: policy.clone(),
            });
        }
        let sc = Scenario::builder(&format!("ext_t2_dlevels_n{n}"))
            .engine(Engine::Statics)
            .job(cfg.job)
            .fleet(cfg.n_max, n)
            .schemes(schemes)
            .speed_model(cfg.speed_model())
            .cost(cfg.cost_model())
            .trials(cfg.trials)
            .seed(cfg.seed ^ (n as u64) << 16)
            .build()
            .expect("valid dlevel scenario");
        let out = sc.run().expect("statics engine cannot fail");
        let cec_mean = out.per_scheme[0].mean(Metric::Computation);
        for (i, (name, _)) in policies.iter().enumerate() {
            let m = out.per_scheme[1 + i].mean(Metric::Computation);
            t.row(vec![
                n.to_string(),
                name.to_string(),
                format!("{m:.4}"),
                format!("{:+.1}", 100.0 * (m - cec_mean) / cec_mean),
            ]);
        }
    }
    t
}

/// Ext-T3: robustness of the Fig. 2c conclusion to the straggler model.
pub fn straggler_sweep_table(
    cfg: &ExperimentConfig,
    slowdowns: &[f64],
    probs: &[f64],
) -> Table {
    let n = *cfg.ns.last().unwrap();
    let mut t = Table::new(&["slowdown", "p", "cec_s", "mlcec_vs_cec_%", "bicec_vs_cec_%"]);
    for &slowdown in slowdowns {
        for &p in probs {
            let sc = Scenario::builder(&format!("ext_t3_s{slowdown}_p{p}"))
                .engine(Engine::Statics)
                .job(cfg.job)
                .fleet(cfg.n_max, n)
                .schemes(SchemeConfig::paper_trio(cfg))
                .speed_model(crate::sim::SpeedModel::BernoulliSlowdown {
                    p,
                    slowdown,
                    jitter: cfg.jitter,
                })
                .cost(cfg.cost_model())
                .trials(cfg.trials)
                .seed(cfg.seed)
                .build()
                .expect("valid straggler-sweep scenario");
            let out = sc.run().expect("statics engine cannot fail");
            let (cm, mm, bm) = (
                out.per_scheme[0].mean(Metric::Finishing),
                out.per_scheme[1].mean(Metric::Finishing),
                out.per_scheme[2].mean(Metric::Finishing),
            );
            t.row(vec![
                format!("{slowdown}"),
                format!("{p}"),
                format!("{cm:.4}"),
                format!("{:+.1}", 100.0 * (mm - cm) / cm),
                format!("{:+.1}", 100.0 * (bm - cm) / cm),
            ]);
        }
    }
    t
}

/// Ext-T4: waste-minimising re-assignment ([10]) vs the schemes' naive
/// positional re-assignment, under Poisson elasticity. Same seed for both
/// policies: reassign is not part of the stream derivation, so each trial
/// replays the identical (speeds, trace) under the other policy.
pub fn reassign_table(cfg: &ExperimentConfig, event_rate: f64) -> Table {
    let job = JobSpec::new(240, 240, 240);
    let schemes = vec![
        SchemeConfig::Cec { k: 2, s: 4 },
        SchemeConfig::Mlcec { k: 2, s: 4, policy: DLevelPolicy::LinearRamp },
    ];
    let policies = [("identity", Reassign::Identity), ("max_overlap", Reassign::MaxOverlap)];
    let outcomes: Vec<_> = policies
        .iter()
        .map(|(pname, policy)| {
            fig1_scale_scenario(
                &format!("ext_t4_reassign_{pname}"),
                cfg,
                job,
                event_rate,
                schemes.clone(),
                *policy,
            )
            .run()
            .expect("trace engine reports failures per trial")
        })
        .collect();
    let mut t = Table::new(&[
        "scheme",
        "policy",
        "avg_waste_taskfrac",
        "avg_computation_s",
        "failures",
    ]);
    for (si, spec) in schemes.iter().enumerate() {
        for ((pname, _), out) in policies.iter().zip(&outcomes) {
            let s = &out.per_scheme[si];
            t.row(vec![
                spec.name().to_string(),
                pname.to_string(),
                format!("{:.4}", s.mean(Metric::TransitionWaste)),
                format!("{:.4}", s.mean(Metric::Computation)),
                s.failures().to_string(),
            ]);
        }
    }
    t
}

/// Ext-T5: the hierarchy ladder at fixed N = 40.
///
/// Two *rate-matched* groups (same per-worker computation budget within a
/// group, so times are directly comparable):
///
/// * rate 5/8 — classic (25, 40) coding [2] vs MLCC with a 35→15 threshold
///   ramp (avg 25) [6, 9]: hierarchy exploits stragglers' partial layers
///   where classic must wait for slow *full-task* completions.
/// * rate 1/4, elastic — CEC vs MLCEC vs BICEC (the paper's Fig. 2a cell).
///
/// The elastic trio runs through the statics scenario; the MLCC ladder is
/// a closed form outside the `Scheme` trait, paired with the scenario's
/// trials via [`Scenario::speeds_per_trial`].
pub fn hierarchy_table(cfg: &ExperimentConfig) -> Table {
    let cost = cfg.cost_model();
    let n = *cfg.ns.last().unwrap();
    let job = cfg.job;
    let sc = Scenario::builder("ext_t5_hierarchy")
        .engine(Engine::Statics)
        .job(job)
        .fleet(cfg.n_max, n)
        .schemes(SchemeConfig::paper_trio(cfg))
        .speed_model(cfg.speed_model())
        .cost(cost)
        .trials(cfg.trials)
        .seed(cfg.seed)
        .build()
        .expect("valid hierarchy scenario");
    let speeds = sc.speeds_per_trial();
    let out = sc.run().expect("statics engine cannot fail");

    let classic = Mlcc::classic(25);
    let mlcc = Mlcc::ramp(20, 35, 15);
    let mut rows: Vec<(String, String, Vec<f64>, Vec<f64>)> = vec![
        ("classic_mds_k25".into(), "5/8".into(), Vec::new(), Vec::new()),
        ("mlcc_35to15".into(), "5/8".into(), Vec::new(), Vec::new()),
    ];
    for sp in &speeds {
        rows[0].2.push(classic.computation_time(n, job, &cost, sp));
        rows[0].3.push(classic.finishing_time(n, job, &cost, sp));
        rows[1].2.push(mlcc.computation_time(n, job, &cost, sp));
        rows[1].3.push(mlcc.finishing_time(n, job, &cost, sp));
    }
    for s in &out.per_scheme {
        rows.push((
            s.scheme.clone(),
            "1/4".into(),
            s.metric_values(Metric::Computation),
            s.metric_values(Metric::Finishing),
        ));
    }
    let mut t = Table::new(&["scheme", "rate", "avg_computation_s", "avg_finishing_s"]);
    for (name, rate, comps, fins) in rows {
        t.row(vec![
            name,
            rate,
            format!("{:.4}", mean(&comps)),
            format!("{:.4}", mean(&fins)),
        ]);
    }
    t
}

/// Ext-T6: heterogeneous-aware allocation ([11, 12]) on a two-tier cluster
/// with *persistent, known* speeds, vs uniform CEC. Deterministic explicit
/// speeds → one trial per cell.
pub fn hetero_table(cfg: &ExperimentConfig) -> Table {
    let mut t = Table::new(&["N", "slow_frac", "cec_s", "hetero_vs_cec_%"]);
    for &n in &[24usize, 32, 40] {
        for slow_frac in [0.25, 0.5, 0.75] {
            let slow_count = (n as f64 * slow_frac).round() as usize;
            let mult: Vec<f64> = (0..n)
                .map(|i| if i < n - slow_count { 1.0 } else { cfg.slowdown })
                .collect();
            let known: Vec<f64> = mult.iter().map(|m| 1.0 / m).collect();
            let sc = Scenario::builder(&format!("ext_t6_n{n}_f{slow_frac}"))
                .engine(Engine::Statics)
                .job(cfg.job)
                .fleet(n, n)
                .schemes(vec![
                    SchemeConfig::Cec { k: cfg.k_cec, s: 12.min(n) },
                    SchemeConfig::Hetero {
                        k: cfg.k_cec,
                        s_avg: 12.min(n),
                        known_speeds: known,
                    },
                ])
                .speed(SpeedSpec::Explicit(mult))
                .cost(cfg.cost_model())
                .trials(1)
                .seed(cfg.seed)
                .build()
                .expect("valid hetero scenario");
            let out = sc.run().expect("statics engine cannot fail");
            let a = out.per_scheme[0].mean(Metric::Computation);
            let b = out.per_scheme[1].mean(Metric::Computation);
            t.row(vec![
                n.to_string(),
                format!("{slow_frac}"),
                format!("{a:.4}"),
                format!("{:+.1}", 100.0 * (b - a) / a),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        ExperimentConfig { trials: 4, ns: vec![20, 40], ..Default::default() }
    }

    #[test]
    fn transition_waste_bicec_is_zero() {
        // 12 trials: P(zero elastic events in every CEC trial) ~ e^-36.
        let t = transition_waste_table(
            &ExperimentConfig { trials: 12, ..quick_cfg() },
            3.0,
        );
        let rendered = t.render();
        let bicec_line = rendered.lines().find(|l| l.contains("bicec")).unwrap();
        // waste column must be exactly 0.0000
        assert!(bicec_line.contains("0.0000"), "{bicec_line}");
        let cec_line = rendered.lines().find(|l| l.contains(" cec")).unwrap();
        assert!(!cec_line.contains(" 0.0000 "), "CEC should pay waste: {cec_line}");
    }

    #[test]
    fn dlevel_table_covers_policies() {
        let t = dlevel_table(&quick_cfg());
        let r = t.render();
        assert!(r.contains("linear_ramp") && r.contains("equalized"));
    }

    #[test]
    fn straggler_sweep_rows() {
        let t = straggler_sweep_table(&quick_cfg(), &[2.0, 10.0], &[0.5]);
        assert_eq!(t.n_rows(), 2);
    }
}

#[cfg(test)]
mod ext_tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        ExperimentConfig { trials: 4, ns: vec![20, 40], ..Default::default() }
    }

    #[test]
    fn reassign_table_max_overlap_never_worse() {
        let t = reassign_table(&quick_cfg(), 3.0);
        let r = t.render();
        let grab = |scheme: &str, policy: &str| -> f64 {
            r.lines()
                .find(|l| l.contains(scheme) && l.contains(policy))
                .and_then(|l| l.split_whitespace().nth(2))
                .and_then(|v| v.parse().ok())
                .unwrap()
        };
        assert!(grab(" cec", "max_overlap") <= grab(" cec", "identity") + 1e-9, "{r}");
    }

    #[test]
    fn hierarchy_ladder_ordering() {
        let t = hierarchy_table(&quick_cfg());
        let r = t.render();
        let grab = |scheme: &str| -> f64 {
            r.lines()
                .find(|l| l.trim_start().starts_with(scheme))
                .and_then(|l| l.split_whitespace().nth(2))
                .and_then(|v| v.parse().ok())
                .unwrap()
        };
        // Within the rate-5/8 group, hierarchy beats classic coding.
        assert!(grab("mlcc_35to15") < grab("classic_mds_k25"), "{r}");
        // Within the elastic group, BICEC has the lowest computation time.
        assert!(grab("bicec") < grab("cec") && grab("bicec") < grab("mlcec"), "{r}");
    }

    #[test]
    fn hetero_table_hetero_wins_at_moderate_skew() {
        // Speed-proportional selection wins decisively up to 50% slow
        // workers at any N (and at 75% for N >= 32); the N=24/75% corner
        // over-concentrates on the 6 fast workers, whose deepened list
        // positions then bind — kept in the table as an honest limitation.
        let t = hetero_table(&quick_cfg());
        for line in t.render().lines().skip(2) {
            let cols: Vec<&str> = line.split_whitespace().collect();
            let (n, frac): (usize, f64) = (cols[0].parse().unwrap(), cols[1].parse().unwrap());
            let pct: f64 = cols[3].parse().unwrap();
            if frac <= 0.5 || n >= 32 {
                assert!(pct < 0.0, "hetero should win here: {line}");
            }
        }
    }
}
