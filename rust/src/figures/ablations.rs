//! Extension ablations (DESIGN.md Ext-T1..T3) — experiments the paper
//! motivates but does not plot.

use crate::config::ExperimentConfig;
use crate::metrics::{mean, Table};
use crate::rng::default_rng;
use crate::sim::{simulate_many, simulate_static, Reassign, TraceMonteCarlo, WorkerSpeeds};
use crate::tas::{Bicec, Cec, DLevelPolicy, HeteroCec, Mlcc, Mlcec, Scheme};
use crate::workload::JobSpec;

/// The Ext-T1/T4 elastic experiment: Fig. 1 geometry (8 slots, floor 4),
/// ~`event_rate` Poisson events per horizon, horizon scaled to the job so
/// events land mid-run. Counter-derived trial streams → the trial pool is
/// parallel yet bit-identical to serial, and every scheme/policy sees the
/// same per-trial (speeds, trace) — the paired comparison.
fn fig1_scale_mc(cfg: &ExperimentConfig, job: JobSpec, event_rate: f64) -> TraceMonteCarlo {
    let cost = cfg.cost_model();
    let horizon = 400.0 * cost.worker_time(job.ops() / 2400, 1.0);
    TraceMonteCarlo {
        n_max: 8,
        n_min: 4,
        n_initial: 8,
        rate: event_rate / horizon,
        horizon,
        speed_model: cfg.speed_model(),
        reassign: Reassign::Identity,
        seed: cfg.seed,
    }
}

/// Ext-T1: transition waste + finishing time under Poisson elasticity.
/// BICEC's zero-waste property is the paper's Sec. 2 claim.
pub fn transition_waste_table(cfg: &ExperimentConfig, event_rate: f64) -> Table {
    // Small geometry (paper Fig. 1 scale) so traces bite mid-run.
    let job = JobSpec::new(240, 240, 240);
    let schemes: Vec<Box<dyn Scheme>> = vec![
        Box::new(Cec::new(2, 4)),
        Box::new(Mlcec::new(2, 4)),
        Box::new(Bicec::new(600, 300, 8)),
    ];
    let cost = cfg.cost_model();
    let mc = fig1_scale_mc(cfg, job, event_rate);
    let mut t = Table::new(&[
        "scheme",
        "avg_waste_taskfrac",
        "avg_reallocs",
        "avg_computation_s",
        "failures",
    ]);
    for scheme in &schemes {
        let (mut wastes, mut reallocs, mut comps) = (Vec::new(), Vec::new(), Vec::new());
        let mut failures = 0usize;
        for r in mc.run(scheme.as_ref(), job, &cost, cfg.trials) {
            match r {
                Ok(out) => {
                    wastes.push(out.transition_waste);
                    reallocs.push(out.reallocations as f64);
                    comps.push(out.computation_time);
                }
                Err(_) => failures += 1,
            }
        }
        t.row(vec![
            scheme.name().to_string(),
            format!("{:.4}", mean(&wastes)),
            format!("{:.2}", mean(&reallocs)),
            format!("{:.4}", mean(&comps)),
            failures.to_string(),
        ]);
    }
    t
}

/// Ext-T2: d-level policy sensitivity for MLCEC (Fig. 2a setup).
pub fn dlevel_table(cfg: &ExperimentConfig) -> Table {
    let cost = cfg.cost_model();
    let policies: Vec<(&str, DLevelPolicy)> = vec![
        ("linear_ramp", DLevelPolicy::LinearRamp),
        (
            "equalized",
            DLevelPolicy::Equalized { p_straggle: cfg.p_straggle, slowdown: cfg.slowdown },
        ),
    ];
    let mut t = Table::new(&["N", "policy", "avg_computation_s", "vs_cec_%"]);
    for &n in &cfg.ns {
        let mut rng = default_rng(cfg.seed ^ (n as u64) << 16);
        let mut speeds_per_trial = Vec::new();
        for _ in 0..cfg.trials {
            speeds_per_trial.push(WorkerSpeeds::sample(&cfg.speed_model(), cfg.n_max, &mut rng));
        }
        let cec = Cec::new(cfg.k_cec, cfg.s_cec);
        let cec_mean = mean(
            &simulate_many(&cec, n, cfg.job, &cost, &speeds_per_trial)
                .iter()
                .map(|r| r.computation_time)
                .collect::<Vec<_>>(),
        );
        for (name, policy) in &policies {
            let scheme = Mlcec::with_policy(cfg.k_cec, cfg.s_cec, policy.clone());
            let m = mean(
                &simulate_many(&scheme, n, cfg.job, &cost, &speeds_per_trial)
                    .iter()
                    .map(|r| r.computation_time)
                    .collect::<Vec<_>>(),
            );
            t.row(vec![
                n.to_string(),
                name.to_string(),
                format!("{m:.4}"),
                format!("{:+.1}", 100.0 * (m - cec_mean) / cec_mean),
            ]);
        }
    }
    t
}

/// Ext-T3: robustness of the Fig. 2c conclusion to the straggler model.
pub fn straggler_sweep_table(
    cfg: &ExperimentConfig,
    slowdowns: &[f64],
    probs: &[f64],
) -> Table {
    let cost = cfg.cost_model();
    let n = *cfg.ns.last().unwrap();
    let cec = Cec::new(cfg.k_cec, cfg.s_cec);
    let mlcec = Mlcec::new(cfg.k_cec, cfg.s_cec);
    let bicec = Bicec::new(cfg.k_bicec, cfg.s_bicec, cfg.n_max);
    let mut t = Table::new(&["slowdown", "p", "cec_s", "mlcec_vs_cec_%", "bicec_vs_cec_%"]);
    for &slowdown in slowdowns {
        for &p in probs {
            let model = crate::sim::SpeedModel::BernoulliSlowdown {
                p,
                slowdown,
                jitter: cfg.jitter,
            };
            let mut rng = default_rng(cfg.seed);
            let speeds: Vec<WorkerSpeeds> = (0..cfg.trials)
                .map(|_| WorkerSpeeds::sample(&model, cfg.n_max, &mut rng))
                .collect();
            let fin = |scheme: &dyn Scheme| {
                simulate_many(scheme, n, cfg.job, &cost, &speeds)
                    .iter()
                    .map(|r| r.finishing_time())
                    .collect::<Vec<_>>()
            };
            let (cm, mm, bm) = (mean(&fin(&cec)), mean(&fin(&mlcec)), mean(&fin(&bicec)));
            t.row(vec![
                format!("{slowdown}"),
                format!("{p}"),
                format!("{cm:.4}"),
                format!("{:+.1}", 100.0 * (mm - cm) / cm),
                format!("{:+.1}", 100.0 * (bm - cm) / cm),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        ExperimentConfig { trials: 4, ns: vec![20, 40], ..Default::default() }
    }

    #[test]
    fn transition_waste_bicec_is_zero() {
        // 12 trials: P(zero elastic events in every CEC trial) ~ e^-36.
        let t = transition_waste_table(
            &ExperimentConfig { trials: 12, ..quick_cfg() },
            3.0,
        );
        let rendered = t.render();
        let bicec_line = rendered.lines().find(|l| l.contains("bicec")).unwrap();
        // waste column must be exactly 0.0000
        assert!(bicec_line.contains("0.0000"), "{bicec_line}");
        let cec_line = rendered.lines().find(|l| l.contains(" cec")).unwrap();
        assert!(!cec_line.contains(" 0.0000 "), "CEC should pay waste: {cec_line}");
    }

    #[test]
    fn dlevel_table_covers_policies() {
        let t = dlevel_table(&quick_cfg());
        let r = t.render();
        assert!(r.contains("linear_ramp") && r.contains("equalized"));
    }

    #[test]
    fn straggler_sweep_rows() {
        let t = straggler_sweep_table(&quick_cfg(), &[2.0, 10.0], &[0.5]);
        assert_eq!(t.n_rows(), 2);
    }
}

/// Ext-T4: waste-minimising re-assignment ([10]) vs the schemes' naive
/// positional re-assignment, under Poisson elasticity.
pub fn reassign_table(cfg: &ExperimentConfig, event_rate: f64) -> Table {
    let job = JobSpec::new(240, 240, 240);
    let cost = cfg.cost_model();
    let schemes: Vec<Box<dyn Scheme>> =
        vec![Box::new(Cec::new(2, 4)), Box::new(Mlcec::new(2, 4))];
    let mut t = Table::new(&[
        "scheme",
        "policy",
        "avg_waste_taskfrac",
        "avg_computation_s",
        "failures",
    ]);
    for scheme in &schemes {
        for (pname, policy) in
            [("identity", Reassign::Identity), ("max_overlap", Reassign::MaxOverlap)]
        {
            // Same seed for both policies: reassign is not part of the
            // stream derivation, so each trial replays the identical
            // (speeds, trace) under the other policy.
            let mc =
                TraceMonteCarlo { reassign: policy, ..fig1_scale_mc(cfg, job, event_rate) };
            let (mut wastes, mut comps) = (Vec::new(), Vec::new());
            let mut failures = 0usize;
            for r in mc.run(scheme.as_ref(), job, &cost, cfg.trials) {
                match r {
                    Ok(out) => {
                        wastes.push(out.transition_waste);
                        comps.push(out.computation_time);
                    }
                    Err(_) => failures += 1,
                }
            }
            t.row(vec![
                scheme.name().to_string(),
                pname.to_string(),
                format!("{:.4}", mean(&wastes)),
                format!("{:.4}", mean(&comps)),
                failures.to_string(),
            ]);
        }
    }
    t
}

/// Ext-T5: the hierarchy ladder at fixed N = 40.
///
/// Two *rate-matched* groups (same per-worker computation budget within a
/// group, so times are directly comparable):
///
/// * rate 5/8 — classic (25, 40) coding [2] vs MLCC with a 35→15 threshold
///   ramp (avg 25) [6, 9]: hierarchy exploits stragglers' partial layers
///   where classic must wait for slow *full-task* completions.
/// * rate 1/4, elastic — CEC vs MLCEC vs BICEC (the paper's Fig. 2a cell).
pub fn hierarchy_table(cfg: &ExperimentConfig) -> Table {
    let cost = cfg.cost_model();
    let n = *cfg.ns.last().unwrap();
    let job = cfg.job;
    let classic = Mlcc::classic(25);
    let mlcc = Mlcc::ramp(20, 35, 15);
    let cec = Cec::new(cfg.k_cec, cfg.s_cec);
    let mlcec = Mlcec::new(cfg.k_cec, cfg.s_cec);
    let bicec = Bicec::new(cfg.k_bicec, cfg.s_bicec, cfg.n_max);
    let mut rng = default_rng(cfg.seed);
    let trials = cfg.trials;
    let mut rows: Vec<(String, String, Vec<f64>, Vec<f64>)> = vec![
        ("classic_mds_k25".into(), "5/8".into(), Vec::new(), Vec::new()),
        ("mlcc_35to15".into(), "5/8".into(), Vec::new(), Vec::new()),
        ("cec".into(), "1/4".into(), Vec::new(), Vec::new()),
        ("mlcec".into(), "1/4".into(), Vec::new(), Vec::new()),
        ("bicec".into(), "1/4".into(), Vec::new(), Vec::new()),
    ];
    for _ in 0..trials {
        let sp = WorkerSpeeds::sample(&cfg.speed_model(), cfg.n_max, &mut rng);
        rows[0].2.push(classic.computation_time(n, job, &cost, &sp));
        rows[0].3.push(classic.finishing_time(n, job, &cost, &sp));
        rows[1].2.push(mlcc.computation_time(n, job, &cost, &sp));
        rows[1].3.push(mlcc.finishing_time(n, job, &cost, &sp));
        for (i, s) in [&cec as &dyn Scheme, &mlcec, &bicec].into_iter().enumerate() {
            let r = simulate_static(s, n, job, &cost, &sp);
            rows[2 + i].2.push(r.computation_time);
            rows[2 + i].3.push(r.finishing_time());
        }
    }
    let mut t = Table::new(&["scheme", "rate", "avg_computation_s", "avg_finishing_s"]);
    for (name, rate, comps, fins) in rows {
        t.row(vec![
            name,
            rate,
            format!("{:.4}", mean(&comps)),
            format!("{:.4}", mean(&fins)),
        ]);
    }
    t
}

/// Ext-T6: heterogeneous-aware allocation ([11, 12]) on a two-tier cluster
/// with *persistent, known* speeds, vs uniform CEC.
pub fn hetero_table(cfg: &ExperimentConfig) -> Table {
    let cost = cfg.cost_model();
    let job = cfg.job;
    let mut t = Table::new(&[
        "N",
        "slow_frac",
        "cec_s",
        "hetero_vs_cec_%",
    ]);
    for &n in &[24usize, 32, 40] {
        for slow_frac in [0.25, 0.5, 0.75] {
            let slow_count = (n as f64 * slow_frac).round() as usize;
            let mult: Vec<f64> = (0..n)
                .map(|i| if i < n - slow_count { 1.0 } else { cfg.slowdown })
                .collect();
            let speeds = WorkerSpeeds::from_vec(mult.clone());
            let known: Vec<f64> = mult.iter().map(|m| 1.0 / m).collect();
            let uniform = Cec::new(cfg.k_cec, 12.min(n));
            let hetero = HeteroCec::new(cfg.k_cec, 12.min(n), known);
            let a = simulate_static(&uniform, n, job, &cost, &speeds).computation_time;
            let b = simulate_static(&hetero, n, job, &cost, &speeds).computation_time;
            t.row(vec![
                n.to_string(),
                format!("{slow_frac}"),
                format!("{a:.4}"),
                format!("{:+.1}", 100.0 * (b - a) / a),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod ext_tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        ExperimentConfig { trials: 4, ns: vec![20, 40], ..Default::default() }
    }

    #[test]
    fn reassign_table_max_overlap_never_worse() {
        let t = reassign_table(&quick_cfg(), 3.0);
        let r = t.render();
        let grab = |scheme: &str, policy: &str| -> f64 {
            r.lines()
                .find(|l| l.contains(scheme) && l.contains(policy))
                .and_then(|l| l.split_whitespace().nth(2))
                .and_then(|v| v.parse().ok())
                .unwrap()
        };
        assert!(grab(" cec", "max_overlap") <= grab(" cec", "identity") + 1e-9, "{r}");
    }

    #[test]
    fn hierarchy_ladder_ordering() {
        let t = hierarchy_table(&quick_cfg());
        let r = t.render();
        let grab = |scheme: &str| -> f64 {
            r.lines()
                .find(|l| l.trim_start().starts_with(scheme))
                .and_then(|l| l.split_whitespace().nth(2))
                .and_then(|v| v.parse().ok())
                .unwrap()
        };
        // Within the rate-5/8 group, hierarchy beats classic coding.
        assert!(grab("mlcc_35to15") < grab("classic_mds_k25"), "{r}");
        // Within the elastic group, BICEC has the lowest computation time.
        assert!(grab("bicec") < grab("cec") && grab("bicec") < grab("mlcec"), "{r}");
    }

    #[test]
    fn hetero_table_hetero_wins_at_moderate_skew() {
        // Speed-proportional selection wins decisively up to 50% slow
        // workers at any N (and at 75% for N >= 32); the N=24/75% corner
        // over-concentrates on the 6 fast workers, whose deepened list
        // positions then bind — kept in the table as an honest limitation.
        let t = hetero_table(&quick_cfg());
        for line in t.render().lines().skip(2) {
            let cols: Vec<&str> = line.split_whitespace().collect();
            let (n, frac): (usize, f64) = (cols[0].parse().unwrap(), cols[1].parse().unwrap());
            let pct: f64 = cols[3].parse().unwrap();
            if frac <= 0.5 || n >= 32 {
                assert!(pct < 0.0, "hetero should win here: {line}");
            }
        }
    }
}
