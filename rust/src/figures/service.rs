//! Multi-tenant service SLO sweep (`hcec service`) — the job-stream
//! counterpart of `figures::cluster`'s single-job N-sweep.
//!
//! Each row runs the paper's scheme trio as a closed-loop job stream
//! through `Engine::Service`: one shared fleet, `conc` tenants in flight
//! at once, every job asking for the same slice of the fleet. The
//! `SimulatedLatency` backend keeps subtask durations on the cost model
//! (× `time_scale`) while the scheduler, the per-tenant reactors and the
//! cross-job re-planning all run for real.
//!
//! Reported metrics are the service's headline SLOs: job latency
//! percentiles (arrival → finish, queue wait included), fleet
//! utilisation (busy slot-seconds over capacity), and preemptions. As
//! concurrency rises, utilisation climbs while tail latency degrades —
//! the coded-elasticity trade the tenancy layer is built to measure.

use crate::config::ExperimentConfig;
use crate::metrics::Table;
use crate::rng::fold_in;
use crate::scenario::{
    ArrivalSpec, BackfillSpec, ClusterBackendSpec, ClusterSpec, Engine, Scenario,
    SchemeConfig, SeedMode, ServiceSpec,
};

/// Default closed-loop concurrency grid for `hcec service`.
pub const SERVICE_CONCURRENCIES: [usize; 3] = [1, 2, 4];

/// The service-engine scenario for one sweep row: `jobs` jobs per scheme
/// streamed through a fleet of `n` slots with `conc` in flight at once.
/// Every job wants the largest scheme's recovery-threshold slice, so the
/// trio is comparable at identical placement pressure.
pub fn service_scenario(
    cfg: &ExperimentConfig,
    n: usize,
    conc: usize,
    jobs: usize,
    trials: usize,
    time_scale: f64,
) -> Scenario {
    let schemes = vec![
        SchemeConfig::Cec { k: cfg.k_cec, s: cfg.s_cec },
        SchemeConfig::mlcec_of(cfg),
        SchemeConfig::Bicec { k: cfg.k_bicec, s_per_worker: cfg.s_bicec },
    ];
    let want = schemes.iter().map(|s| s.min_workers()).max().unwrap();
    assert!(n >= want, "service sweep fleet {n} below the scheme floor {want}");
    Scenario::builder(&format!("service_sim_n{n}_c{conc}"))
        .engine(Engine::Service)
        .job(cfg.job)
        .fleet(n, n)
        .schemes(schemes)
        .speed_model(cfg.speed_model())
        .cost(cfg.cost_model())
        .cluster(ClusterSpec {
            backend: ClusterBackendSpec::SimulatedLatency,
            time_scale,
            preempt_after_first: 0,
            backfill: BackfillSpec::On,
        })
        .service(ServiceSpec {
            arrival: ArrivalSpec::Closed { concurrency: conc },
            jobs,
            want,
            high_priority_every: 0,
        })
        .trials(trials)
        .seed(fold_in(cfg.seed, (n * 1000 + conc) as u64))
        .seed_mode(SeedMode::PerTrial)
        .build()
        .expect("valid service sweep scenario")
}

/// One row per (concurrency, scheme): stream latency percentiles, fleet
/// utilisation and preemption counts, averaged over trials.
pub fn service_table(
    cfg: &ExperimentConfig,
    n: usize,
    concurrencies: &[usize],
    jobs: usize,
    trials: usize,
    time_scale: f64,
) -> Table {
    let mut t = Table::new(&[
        "conc",
        "scheme",
        "jobs",
        "lat_p50_s",
        "lat_p95_s",
        "lat_p99_s",
        "util",
        "preempts",
        "failures",
    ]);
    for &conc in concurrencies {
        let sc = service_scenario(cfg, n, conc, jobs, trials, time_scale);
        let out = sc.run().expect("service engine records per-trial failures");
        for s in &out.per_scheme {
            let stats: Vec<_> = s.ok_trials().filter_map(|t| t.service).collect();
            let k = stats.len().max(1) as f64;
            let mean_of = |f: fn(&crate::scenario::ServiceStats) -> f64| -> f64 {
                stats.iter().map(f).sum::<f64>() / k
            };
            t.row(vec![
                conc.to_string(),
                s.scheme.clone(),
                stats.iter().map(|v| v.jobs).sum::<usize>().to_string(),
                format!("{:.4}", mean_of(|v| v.latency_p50)),
                format!("{:.4}", mean_of(|v| v.latency_p95)),
                format!("{:.4}", mean_of(|v| v.latency_p99)),
                format!("{:.3}", mean_of(|v| v.utilisation)),
                stats.iter().map(|v| v.preemptions).sum::<usize>().to_string(),
                s.failures().to_string(),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_scenario_round_trips_through_toml() {
        let cfg = ExperimentConfig::default();
        let sc = service_scenario(&cfg, 40, 2, 3, 1, 0.01);
        let back = Scenario::from_toml(&sc.to_toml()).unwrap();
        assert_eq!(back.to_doc(), sc.to_doc());
        assert_eq!(back.engine, Engine::Service);
        assert_eq!(back.service, sc.service);
    }

    #[test]
    fn service_table_runs_one_small_sweep_point() {
        // One concurrency level, short stream, aggressively scaled down:
        // the scheduler + per-tenant reactors finish in well under a
        // second of wall clock. The trio yields three rows.
        let cfg = ExperimentConfig::default();
        let t = service_table(&cfg, 40, &[2], 2, 1, 0.002);
        assert_eq!(t.n_rows(), 3);
        let r = t.render();
        assert!(r.contains("bicec"), "{r}");
        assert!(r.contains("lat_p99_s"), "{r}");
    }
}
