//! Drop-rate-vs-recovery sweep for the socket transport (PR 9) — how much
//! event/command loss the retry + crash-as-leave machinery absorbs before
//! jobs start failing outright.
//!
//! Each row runs the paper's scheme trio through `Engine::Cluster` with a
//! symmetric `[chaos]` drop rate on both link directions and the
//! `SimulatedLatency` backend (the loss model and the recovery ledger are
//! transport-generic, so the cheap backend measures the same machinery the
//! native one ships). The `kind` parameter selects the transport under
//! test: `Mpsc` keeps the sweep self-contained in-process (what the unit
//! tests run); `Tcp` reruns the identical scenario over real sockets and
//! spawned worker processes — the cross-check that loss behaves the same
//! on both sides of the `Link` trait.
//!
//! Reported per (drop, scheme): mean wall computation, mean transition
//! waste, watchdog retries, crashes absorbed (a connection loss lands
//! here as crash-as-leave), and per-trial failures.

use crate::config::ExperimentConfig;
use crate::metrics::Table;
use crate::rng::fold_in;
use crate::scenario::{
    ChaosConfig, ClusterBackendSpec, ClusterSpec, Engine, FaultRates, Metric, Scenario,
    SchemeConfig, SeedMode, TransportKind, TransportSpec,
};

/// Default drop-rate grid for the transport sweep: quiet links, then
/// escalating symmetric loss up to one packet in ten.
pub const TRANSPORT_DROP_RATES: [f64; 4] = [0.0, 0.02, 0.05, 0.10];

/// The cluster-engine scenario for one sweep point: the scheme trio at
/// fleet size `n` with symmetric drop rate `drop` on both directions and
/// the transport `kind` under test.
pub fn transport_scenario(
    cfg: &ExperimentConfig,
    n: usize,
    drop: f64,
    trials: usize,
    time_scale: f64,
    kind: TransportKind,
) -> Scenario {
    assert!(n >= cfg.s_cec, "transport sweep N={n} below S={}", cfg.s_cec);
    let schemes = vec![
        SchemeConfig::Cec { k: cfg.k_cec, s: cfg.s_cec },
        SchemeConfig::mlcec_of(cfg),
        SchemeConfig::Bicec { k: cfg.k_bicec, s_per_worker: cfg.s_bicec },
    ];
    let rates = FaultRates { drop, ..Default::default() };
    let chaos = ChaosConfig {
        // Fault stream independent of the job seed, folded per drop point
        // so the loss pattern varies across the sweep.
        seed: fold_in(cfg.seed, (drop * 1000.0) as u64),
        cmd: rates,
        evt: rates,
        ..Default::default()
    };
    Scenario::builder(&format!("transport_drop{}", (drop * 100.0) as usize))
        .engine(Engine::Cluster)
        .job(cfg.job)
        .fleet(n, n)
        .schemes(schemes)
        .speed_model(cfg.speed_model())
        .cost(cfg.cost_model())
        .cluster(ClusterSpec {
            backend: ClusterBackendSpec::SimulatedLatency,
            time_scale,
            preempt_after_first: 0,
            backfill: crate::scenario::BackfillSpec::On,
        })
        .chaos(chaos)
        .transport(TransportSpec { kind, ..Default::default() })
        .trials(trials)
        .seed(fold_in(cfg.seed, (drop * 10_000.0) as u64))
        .seed_mode(SeedMode::PerTrial)
        .build()
        .expect("valid transport sweep scenario")
}

/// One row per (drop rate, scheme): mean wall computation, mean transition
/// waste, watchdog retries spent recovering lost packets, crashes absorbed
/// and per-trial failures.
pub fn transport_table(
    cfg: &ExperimentConfig,
    n: usize,
    drops: &[f64],
    trials: usize,
    time_scale: f64,
    kind: TransportKind,
) -> Table {
    let mut t = Table::new(&[
        "drop",
        "scheme",
        "wall_mean_s",
        "waste_mean",
        "retries",
        "crashes",
        "failures",
    ]);
    for &drop in drops {
        let sc = transport_scenario(cfg, n, drop, trials, time_scale, kind);
        let out = sc.run().expect("cluster engine records per-trial failures");
        for s in &out.per_scheme {
            let retries: usize = s.ok_trials().map(|t| t.retries).sum();
            let crashes: usize = s.ok_trials().map(|t| t.crashes_absorbed).sum();
            t.row(vec![
                format!("{drop:.2}"),
                s.scheme.clone(),
                format!("{:.4}", s.mean(Metric::Computation)),
                format!("{:.4}", s.mean(Metric::TransitionWaste)),
                retries.to_string(),
                crashes.to_string(),
                s.failures().to_string(),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_scenario_round_trips_through_toml() {
        let cfg = ExperimentConfig::default();
        let sc = transport_scenario(&cfg, 40, 0.05, 2, 0.05, TransportKind::Tcp);
        let text = sc.to_toml();
        assert!(text.contains("kind = \"tcp\""), "{text}");
        let back = Scenario::from_toml(&text).unwrap();
        assert_eq!(back.to_doc(), sc.to_doc());
        assert_eq!(back.transport.kind, TransportKind::Tcp);
        assert!(back.chaos.is_some());
    }

    #[test]
    fn transport_table_runs_one_lossy_point_in_process() {
        // One sweep point over mpsc links (no processes spawned in unit
        // tests), 5% symmetric drop, aggressively scaled down. The trio
        // yields three rows and nothing fails outright at this rate.
        let cfg = ExperimentConfig::default();
        let t = transport_table(&cfg, 40, &[0.05], 1, 0.02, TransportKind::Mpsc);
        assert_eq!(t.n_rows(), 3);
        let r = t.render();
        assert!(r.contains("0.05"), "{r}");
        assert!(r.contains("bicec"), "{r}");
    }
}
