//! Service-layer N-sweep on the event-driven cluster (`hcec cluster`) —
//! the real-coordinator counterpart of `figures::sweep`'s simulation
//! sweeps.
//!
//! Each row runs the paper's scheme trio through `Engine::Cluster` with
//! the `SimulatedLatency` backend: real reactor, real channels, real
//! worker threads and mid-job Poisson churn, with each subtask's gemm
//! replaced by its cost-model duration (× `time_scale`). Churn scales
//! like the simulation sweeps: fleet-wide rate ∝ N at fixed per-node
//! event count, horizon ∝ the shrinking run (`2 · S · tau(N)`).
//!
//! Reported metrics are mean wall-clock computation time, the planner's
//! mean **transition waste** per scheme (the paper's re-allocation cost
//! criterion, now measured on the real coordinator — zero for BICEC by
//! construction), planner re-plans applied, and the per-trial failure
//! count (a churn draw the reactor's ledger check rejects is a recorded
//! failure, not a crash). The `backfill` knob selects the planner's
//! re-balancing policy per row — `hcec cluster --backfill compare` sweeps
//! both and emits paired `<scheme>`/`<scheme>+backfill` columns' rows.

use crate::config::ExperimentConfig;
use crate::metrics::Table;
use crate::rng::fold_in;
use crate::scenario::{
    BackfillSpec, ClusterBackendSpec, ClusterSpec, ElasticitySpec, Engine, Metric,
    Scenario, SchemeConfig, SeedMode,
};
use crate::sim::Reassign;
use crate::tas::Scheme;

/// Default fleet grid for `hcec cluster` (the 2560 point costs whole
/// seconds of thread churn; opt in via `--ns`).
pub const CLUSTER_NS: [usize; 3] = [40, 160, 640];

/// The cluster-engine scenario for one sweep row at fleet size `n`.
/// `events_per_node` is the expected elastic events per slot within one
/// horizon; `time_scale` converts cost-model seconds to wall sleeps.
pub fn cluster_scenario(
    cfg: &ExperimentConfig,
    n: usize,
    events_per_node: f64,
    trials: usize,
    time_scale: f64,
    backfill: BackfillSpec,
) -> Scenario {
    assert!(n >= cfg.s_cec, "cluster sweep N={n} below S={}", cfg.s_cec);
    let cost = cfg.cost_model();
    let schemes = vec![
        SchemeConfig::Cec { k: cfg.k_cec, s: cfg.s_cec },
        SchemeConfig::mlcec_of(cfg),
        SchemeConfig::Bicec { k: cfg.k_bicec, s_per_worker: cfg.s_bicec },
    ];
    let cec = crate::tas::Cec::new(cfg.k_cec, cfg.s_cec);
    let tau = cost.worker_time(cec.subtask_ops(cfg.job.u, cfg.job.w, cfg.job.v, n), 1.0);
    let horizon = 2.0 * cfg.s_cec as f64 * tau;
    let mid = schemes.iter().map(|s| s.min_active_mid_job()).max().unwrap();
    Scenario::builder(&format!("cluster_sim_n{n}"))
        .engine(Engine::Cluster)
        .job(cfg.job)
        .fleet(n, n)
        .schemes(schemes)
        .speed_model(cfg.speed_model())
        .cost(cost)
        .elasticity(ElasticitySpec::Churn {
            n_min: (n / 2).max(mid),
            n_initial: n,
            rate: events_per_node * n as f64 / horizon,
            horizon,
            reassign: Reassign::Identity,
        })
        .cluster(ClusterSpec {
            backend: ClusterBackendSpec::SimulatedLatency,
            time_scale,
            preempt_after_first: 0,
            backfill,
        })
        .trials(trials)
        .seed(fold_in(cfg.seed, n as u64))
        .seed_mode(SeedMode::PerTrial)
        .build()
        .expect("valid cluster sweep scenario")
}

/// One row per (N, scheme row): mean wall computation, mean transition
/// waste (the planner's priced deltas — the DES-comparable column), planner
/// re-plans applied, completions received, failures. `backfill = compare`
/// doubles the scheme rows into paired off/on comparisons.
pub fn cluster_table(
    cfg: &ExperimentConfig,
    ns: &[usize],
    events_per_node: f64,
    trials: usize,
    time_scale: f64,
    backfill: BackfillSpec,
) -> Table {
    let mut t = Table::new(&[
        "N",
        "scheme",
        "wall_mean_s",
        "waste_mean",
        "replans",
        "completions",
        "failures",
        "q_peak",
        "bp_waits",
    ]);
    for &n in ns {
        let sc = cluster_scenario(cfg, n, events_per_node, trials, time_scale, backfill);
        let out = sc.run().expect("cluster engine records per-trial failures");
        for s in &out.per_scheme {
            let replans: usize = s.ok_trials().map(|t| t.reallocations).sum();
            let completions: u64 = s.ok_trials().map(|t| t.completions).sum();
            // Queue high-water mark is a gauge (worst trial); backpressure
            // stalls accumulate across trials.
            let q_peak = s.ok_trials().map(|t| t.evt_queue_peak).max().unwrap_or(0);
            let bp_waits: usize = s.ok_trials().map(|t| t.backpressure_waits).sum();
            t.row(vec![
                n.to_string(),
                s.scheme.clone(),
                format!("{:.4}", s.mean(Metric::Computation)),
                format!("{:.4}", s.mean(Metric::TransitionWaste)),
                replans.to_string(),
                completions.to_string(),
                s.failures().to_string(),
                q_peak.to_string(),
                bp_waits.to_string(),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_scenario_round_trips_through_toml() {
        let cfg = ExperimentConfig::default();
        let sc = cluster_scenario(&cfg, 40, 0.25, 2, 0.05, BackfillSpec::On);
        let back = Scenario::from_toml(&sc.to_toml()).unwrap();
        assert_eq!(back.to_doc(), sc.to_doc());
        assert_eq!(back.engine, Engine::Cluster);
        assert_eq!(back.cluster.backfill, BackfillSpec::On);
    }

    #[test]
    fn cluster_table_runs_one_small_row_per_scheme() {
        // One N=40 sweep point, 1 trial, aggressively scaled down: the
        // real reactor + 40 threads finish in tens of milliseconds. The
        // trio yields three rows; BICEC's waste column must be zero.
        let cfg = ExperimentConfig::default();
        let t = cluster_table(&cfg, &[40], 0.25, 1, 0.02, BackfillSpec::On);
        assert_eq!(t.n_rows(), 3);
        let r = t.render();
        assert!(r.contains("40"), "{r}");
        assert!(r.contains("bicec"), "{r}");
    }

    #[test]
    fn cluster_table_compare_mode_pairs_rows() {
        let cfg = ExperimentConfig::default();
        let t = cluster_table(&cfg, &[40], 0.25, 1, 0.02, BackfillSpec::Compare);
        assert_eq!(t.n_rows(), 6, "compare doubles every scheme row");
        let r = t.render();
        assert!(r.contains("cec+backfill"), "{r}");
    }
}
