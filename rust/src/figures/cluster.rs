//! Service-layer N-sweep on the event-driven cluster (`hcec cluster`) —
//! the real-coordinator counterpart of `figures::sweep`'s simulation
//! sweeps.
//!
//! Each row runs the paper's scheme trio through `Engine::Cluster` with
//! the `SimulatedLatency` backend: real reactor, real channels, real
//! worker threads and mid-job Poisson churn, with each subtask's gemm
//! replaced by its cost-model duration (× `time_scale`). Churn scales
//! like the simulation sweeps: fleet-wide rate ∝ N at fixed per-node
//! event count, horizon ∝ the shrinking run (`2 · S · tau(N)`).
//!
//! Reported metric is mean wall-clock computation time plus the absorbed
//! elastic events and the per-trial failure count (a churn draw the
//! reactor's ledger check rejects is a recorded failure, not a crash).

use crate::config::ExperimentConfig;
use crate::metrics::Table;
use crate::rng::fold_in;
use crate::scenario::{
    ClusterBackendSpec, ClusterSpec, ElasticitySpec, Engine, Metric, Scenario,
    SchemeConfig, SeedMode,
};
use crate::sim::Reassign;
use crate::tas::Scheme;

/// Default fleet grid for `hcec cluster` (the 2560 point costs whole
/// seconds of thread churn; opt in via `--ns`).
pub const CLUSTER_NS: [usize; 3] = [40, 160, 640];

/// The cluster-engine scenario for one sweep row at fleet size `n`.
/// `events_per_node` is the expected elastic events per slot within one
/// horizon; `time_scale` converts cost-model seconds to wall sleeps.
pub fn cluster_scenario(
    cfg: &ExperimentConfig,
    n: usize,
    events_per_node: f64,
    trials: usize,
    time_scale: f64,
) -> Scenario {
    assert!(n >= cfg.s_cec, "cluster sweep N={n} below S={}", cfg.s_cec);
    let cost = cfg.cost_model();
    let schemes = vec![
        SchemeConfig::Cec { k: cfg.k_cec, s: cfg.s_cec },
        SchemeConfig::mlcec_of(cfg),
        SchemeConfig::Bicec { k: cfg.k_bicec, s_per_worker: cfg.s_bicec },
    ];
    let cec = crate::tas::Cec::new(cfg.k_cec, cfg.s_cec);
    let tau = cost.worker_time(cec.subtask_ops(cfg.job.u, cfg.job.w, cfg.job.v, n), 1.0);
    let horizon = 2.0 * cfg.s_cec as f64 * tau;
    let mid = schemes.iter().map(|s| s.min_active_mid_job()).max().unwrap();
    Scenario::builder(&format!("cluster_sim_n{n}"))
        .engine(Engine::Cluster)
        .job(cfg.job)
        .fleet(n, n)
        .schemes(schemes)
        .speed_model(cfg.speed_model())
        .cost(cost)
        .elasticity(ElasticitySpec::Churn {
            n_min: (n / 2).max(mid),
            n_initial: n,
            rate: events_per_node * n as f64 / horizon,
            horizon,
            reassign: Reassign::Identity,
        })
        .cluster(ClusterSpec {
            backend: ClusterBackendSpec::SimulatedLatency,
            time_scale,
            preempt_after_first: 0,
        })
        .trials(trials)
        .seed(fold_in(cfg.seed, n as u64))
        .seed_mode(SeedMode::PerTrial)
        .build()
        .expect("valid cluster sweep scenario")
}

/// One row per N: per-scheme wall computation means, elastic events
/// absorbed by the reactor, completions received, failures.
pub fn cluster_table(
    cfg: &ExperimentConfig,
    ns: &[usize],
    events_per_node: f64,
    trials: usize,
    time_scale: f64,
) -> Table {
    let mut t = Table::new(&[
        "N",
        "cec_wall_s",
        "mlcec_wall_s",
        "bicec_wall_s",
        "events_absorbed",
        "completions",
        "failures",
    ]);
    for &n in ns {
        let sc = cluster_scenario(cfg, n, events_per_node, trials, time_scale);
        let out = sc.run().expect("cluster engine records per-trial failures");
        let walls: Vec<f64> =
            out.per_scheme.iter().map(|s| s.mean(Metric::Computation)).collect();
        let events: usize = out
            .per_scheme
            .iter()
            .flat_map(|s| s.ok_trials().map(|t| t.reallocations))
            .sum();
        let completions: u64 = out
            .per_scheme
            .iter()
            .flat_map(|s| s.ok_trials().map(|t| t.completions))
            .sum();
        let failures: usize = out.per_scheme.iter().map(|s| s.failures()).sum();
        t.row(vec![
            n.to_string(),
            format!("{:.4}", walls[0]),
            format!("{:.4}", walls[1]),
            format!("{:.4}", walls[2]),
            events.to_string(),
            completions.to_string(),
            failures.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_scenario_round_trips_through_toml() {
        let cfg = ExperimentConfig::default();
        let sc = cluster_scenario(&cfg, 40, 0.25, 2, 0.05);
        let back = Scenario::from_toml(&sc.to_toml()).unwrap();
        assert_eq!(back.to_doc(), sc.to_doc());
        assert_eq!(back.engine, Engine::Cluster);
    }

    #[test]
    fn cluster_table_runs_one_small_row() {
        // One N=40 row, 1 trial, aggressively scaled down: the real
        // reactor + 40 threads finish in tens of milliseconds.
        let cfg = ExperimentConfig::default();
        let t = cluster_table(&cfg, &[40], 0.25, 1, 0.02);
        assert_eq!(t.n_rows(), 1);
        let r = t.render();
        assert!(r.contains("40"), "{r}");
    }
}
