//! Figure harness: regenerates every table/figure of the paper's
//! evaluation (Sec. 3) plus the extension ablations (DESIGN.md §5).
//!
//! Each generator returns a `metrics::Table` whose rows are the series the
//! paper plots; `hcec figure <id>` renders it and optionally writes CSV.

mod ablations;
mod cluster;
mod fig1;
mod fig2;
mod service;
mod sweep;
mod transport;

pub use ablations::{
    dlevel_table, hetero_table, hierarchy_table, reassign_table, straggler_sweep_table,
    transition_waste_table,
};
pub use cluster::{cluster_scenario, cluster_table, CLUSTER_NS};
pub use fig1::{fig1_grid, fig1_table};
pub use fig2::{fig2_scenario, fig2_series, fig2_table, Fig2Point, Metric};
pub use service::{service_scenario, service_table, SERVICE_CONCURRENCIES};
pub use sweep::{scaling_scenarios, scaling_table, SCALING_NS};
pub use transport::{transport_scenario, transport_table, TRANSPORT_DROP_RATES};
