//! Fig. 1 — the task-allocation grids for the motivating example
//! (K = 2, S = 4; BICEC K = 600, S = 300) at N ∈ {8, 6, 4}.
//!
//! The paper draws a (worker x subtask) grid with checkmarks on selected
//! subtasks; `fig1_grid` renders the same as ASCII, and `fig1_table`
//! summarises the d-levels per scheme so the bench can assert the exact
//! paper values.

use crate::metrics::Table;
use crate::tas::{Allocation, Bicec, Cec, DLevelPolicy, Mlcec, Scheme};

/// ASCII checkbox grid of an allocation (PerSet schemes): rows = workers,
/// columns = sets; `x` marks a selected subtask.
pub fn render_grid(alloc: &Allocation) -> String {
    let n = alloc.workers();
    let sets = match alloc.rule {
        crate::tas::RecoveryRule::PerSet { sets, .. } => sets,
        crate::tas::RecoveryRule::Global { .. } => {
            // BICEC: show per-worker list lengths instead of a set grid.
            let mut out = String::new();
            for (w, list) in alloc.lists.iter().enumerate() {
                out.push_str(&format!(
                    "worker {w}: subtasks {}..{} (static)\n",
                    list.first().map(|i| i.group).unwrap_or(0),
                    list.last().map(|i| i.group + 1).unwrap_or(0)
                ));
            }
            return out;
        }
    };
    let mut out = String::from("        ");
    for m in 0..sets {
        out.push_str(&format!("{m:>3}"));
    }
    out.push('\n');
    for w in 0..n {
        out.push_str(&format!("worker{w:>2}"));
        for m in 0..sets {
            let has = alloc.lists[w].iter().any(|i| i.group == m);
            out.push_str(if has { "  x" } else { "  ." });
        }
        out.push('\n');
    }
    out
}

/// The three schemes' grids at one N (paper Fig. 1 column).
pub fn fig1_grid(n: usize) -> String {
    let cec = Cec::new(2, 4).allocate(n);
    let mlcec = if n == 8 {
        Mlcec::with_policy(2, 4, DLevelPolicy::PaperFig1).allocate(n)
    } else {
        Mlcec::new(2, 4).allocate(n)
    };
    let bicec = Bicec::new(600, 300, 8).allocate(n);
    format!(
        "== N = {n} ==\n-- CEC (K=2, S=4) --\n{}\n-- MLCEC (K=2, S=4) --\n{}\n-- BICEC (K=600, S=300) --\n{}",
        render_grid(&cec),
        render_grid(&mlcec),
        render_grid(&bicec)
    )
}

/// d-levels per set for CEC vs MLCEC across the Fig. 1 grid.
pub fn fig1_table() -> Table {
    let mut t = Table::new(&["N", "scheme", "d_levels", "sum", "transition"]);
    for n in [8usize, 6, 4] {
        for (name, alloc) in [
            ("cec", Cec::new(2, 4).allocate(n)),
            (
                "mlcec",
                if n == 8 {
                    Mlcec::with_policy(2, 4, DLevelPolicy::PaperFig1).allocate(n)
                } else {
                    Mlcec::new(2, 4).allocate(n)
                },
            ),
        ] {
            let d = alloc.contributors_per_set().unwrap();
            let sum: usize = d.iter().sum();
            t.row(vec![
                n.to_string(),
                name.to_string(),
                format!("{d:?}"),
                sum.to_string(),
                "realloc".to_string(),
            ]);
        }
        t.row(vec![
            n.to_string(),
            "bicec".to_string(),
            "static ranges".to_string(),
            (n * 300).to_string(),
            "zero-waste".to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fig1_mlcec_levels_in_table() {
        let t = fig1_table();
        let rendered = t.render();
        assert!(rendered.contains("[2, 2, 3, 4, 4, 5, 6, 6]"), "{rendered}");
    }

    #[test]
    fn grid_marks_exactly_s_per_worker() {
        let g = render_grid(&Cec::new(2, 4).allocate(8));
        for line in g.lines().skip(1) {
            let marks = line.matches(" x").count();
            assert_eq!(marks, 4, "line: {line}");
        }
    }

    #[test]
    fn fig1_grid_covers_all_three_schemes() {
        for n in [8, 6, 4] {
            let s = fig1_grid(n);
            assert!(s.contains("CEC") && s.contains("MLCEC") && s.contains("BICEC"));
        }
    }

    #[test]
    fn bicec_grid_shows_static_ranges() {
        let s = fig1_grid(6);
        assert!(s.contains("(static)"));
        assert!(s.contains("subtasks 0..300"));
    }
}
