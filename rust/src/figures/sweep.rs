//! Large-N scenario sweep — the scaling study behind ROADMAP's
//! "trace-driven service benchmarks at N >> 40".
//!
//! The paper evaluates N ∈ [20, 40] (Fig. 2); the regimes studied in the
//! CEC baseline (Yang et al.) and the transition-waste follow-up (Dau et
//! al.) motivate much larger fleets with proportionally more elastic
//! churn. The sweep holds the paper's code geometry fixed (CEC/MLCEC
//! (K, S) = (10, 20); BICEC (800, 80·N)) and scales three things together:
//!
//! * worker count N over powers of 4 ([`SCALING_NS`] = {40, 160, 640,
//!   2560} by default),
//! * fleet-wide elastic event rate ∝ N (fixed per-node churn, like
//!   spot-market preemption), and
//! * the trace horizon ∝ 1/N — runs finish faster with more workers, so
//!   the churn window tracks the shrinking run.
//!
//! All randomness is counter-derived per trial (`rng::trial_rng` keyed by
//! `fold_in(cfg.seed, N)`), so every cell is reproducible in isolation and
//! the parallel trial pools are bit-identical to serial. The static
//! columns use one straggler draw per trial shared by all three schemes
//! (paired comparison, as in Fig. 2); the trace columns pair trials the
//! same way through the shared per-trial stream.
//!
//! Reported metric is mean *computation* time (Fig. 2a's axis): BICEC's
//! K = 800 decode is N-independent and would swamp the scaling signal.

use crate::config::ExperimentConfig;
use crate::metrics::{mean, Table};
use crate::rng::{fold_in, trial_rng};
use crate::sim::{simulate_many, Reassign, TraceMonteCarlo, WorkerSpeeds};
use crate::tas::{Bicec, Cec, Mlcec, Scheme};

/// Default worker-count grid for the scaling sweep.
pub const SCALING_NS: [usize; 4] = [40, 160, 640, 2560];

/// One row per N: paired static computation means and paired elastic-trace
/// computation means, plus CEC's transition waste and the failure count.
/// `events_per_node` is the expected number of elastic events per worker
/// slot within one trace horizon (fleet-wide rate = events_per_node · N /
/// horizon).
pub fn scaling_table(
    cfg: &ExperimentConfig,
    ns: &[usize],
    events_per_node: f64,
    trials: usize,
) -> Table {
    let cost = cfg.cost_model();
    let job = cfg.job;
    let cec = Cec::new(cfg.k_cec, cfg.s_cec);
    let mlcec = Mlcec::new(cfg.k_cec, cfg.s_cec);
    let mut t = Table::new(&[
        "N",
        "static_cec_s",
        "static_mlcec_%",
        "static_bicec_%",
        "trace_cec_s",
        "trace_mlcec_%",
        "trace_bicec_%",
        "cec_waste",
        "failures",
    ]);
    for &n in ns {
        assert!(n >= cfg.s_cec, "sweep N={n} below S={}", cfg.s_cec);
        let bicec = Bicec::new(cfg.k_bicec, cfg.s_bicec, n);
        let seed_n = fold_in(cfg.seed, n as u64);

        // -- static: paired straggler draws from counter streams.
        let speeds: Vec<WorkerSpeeds> = (0..trials)
            .map(|i| {
                let mut rng = trial_rng(seed_n, i as u64);
                WorkerSpeeds::sample(&cfg.speed_model(), n, &mut rng)
            })
            .collect();
        let comp_mean = |scheme: &dyn Scheme| -> f64 {
            mean(
                &simulate_many(scheme, n, job, &cost, &speeds)
                    .iter()
                    .map(|r| r.computation_time)
                    .collect::<Vec<_>>(),
            )
        };
        let (sc, sm, sb) = (comp_mean(&cec), comp_mean(&mlcec), comp_mean(&bicec));

        // -- trace: fixed per-node churn; horizon tracks the faster run
        // (~2 unstraggled CEC sweeps).
        let tau = cost.worker_time(cec.subtask_ops(job.u, job.w, job.v, n), 1.0);
        let horizon = 2.0 * cfg.s_cec as f64 * tau;
        let mc = TraceMonteCarlo {
            n_max: n,
            n_min: (n / 2).max(cfg.s_cec),
            n_initial: n,
            rate: events_per_node * n as f64 / horizon,
            horizon,
            speed_model: cfg.speed_model(),
            reassign: Reassign::Identity,
            seed: seed_n,
        };
        let mut failures = 0usize;
        let mut waste = Vec::new();
        let mut tmean = [0.0f64; 3];
        for (si, scheme) in
            [&cec as &dyn Scheme, &mlcec, &bicec].into_iter().enumerate()
        {
            let mut comps = Vec::new();
            for r in mc.run(scheme, job, &cost, trials) {
                match r {
                    Ok(out) => {
                        comps.push(out.computation_time);
                        if si == 0 {
                            waste.push(out.transition_waste);
                        }
                    }
                    Err(_) => failures += 1,
                }
            }
            tmean[si] = mean(&comps);
        }

        t.row(vec![
            n.to_string(),
            format!("{sc:.4}"),
            format!("{:+.1}", 100.0 * (sm - sc) / sc),
            format!("{:+.1}", 100.0 * (sb - sc) / sc),
            format!("{:.4}", tmean[0]),
            format!("{:+.1}", 100.0 * (tmean[1] - tmean[0]) / tmean[0]),
            format!("{:+.1}", 100.0 * (tmean[2] - tmean[0]) / tmean[0]),
            format!("{:.4}", mean(&waste)),
            failures.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        ExperimentConfig { trials: 5, ..Default::default() }
    }

    fn grab(table_render: &str, row: usize, col: usize) -> f64 {
        table_render
            .lines()
            .nth(2 + row) // skip header + rule
            .and_then(|l| l.split_whitespace().nth(col))
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("cell ({row}, {col}) of:\n{table_render}"))
    }

    #[test]
    fn scaling_table_static_time_shrinks_with_n() {
        let cfg = quick_cfg();
        let t = scaling_table(&cfg, &[40, 160], 1.0, 5);
        assert_eq!(t.n_rows(), 2);
        let r = t.render();
        let (t40, t160) = (grab(&r, 0, 1), grab(&r, 1, 1));
        assert!(
            t40 > 2.0 * t160,
            "4x the workers must shrink CEC computation well past 2x: {t40} vs {t160}"
        );
    }

    #[test]
    fn scaling_table_is_deterministic() {
        let cfg = quick_cfg();
        let a = scaling_table(&cfg, &[40, 160], 1.0, 4).render();
        let b = scaling_table(&cfg, &[40, 160], 1.0, 4).render();
        assert_eq!(a, b);
    }

    #[test]
    fn scaling_table_trace_survives_churn() {
        // Per-node churn of 1 event/horizon at N=40: some trials realloc,
        // and the sweep must not fail wholesale.
        let cfg = quick_cfg();
        let t = scaling_table(&cfg, &[40], 1.0, 5);
        let r = t.render();
        let failures = grab(&r, 0, 8);
        assert!(failures <= 3.0, "too many failed trials:\n{r}");
        let trace_cec = grab(&r, 0, 4);
        assert!(trace_cec.is_finite() && trace_cec > 0.0, "{r}");
    }
}
