//! Large-N scenario sweep — the scaling study behind ROADMAP's
//! "trace-driven service benchmarks at N >> 40".
//!
//! The paper evaluates N ∈ [20, 40] (Fig. 2); the regimes studied in the
//! CEC baseline (Yang et al.) and the transition-waste follow-up (Dau et
//! al.) motivate much larger fleets with proportionally more elastic
//! churn. The sweep holds the paper's code geometry fixed (CEC/MLCEC
//! (K, S) = (10, 20); BICEC (800, 80·N)) and scales three things together:
//!
//! * worker count N over powers of 4 ([`SCALING_NS`] = {40, 160, 640,
//!   2560} by default),
//! * fleet-wide elastic event rate ∝ N (fixed per-node churn, like
//!   spot-market preemption), and
//! * the trace horizon ∝ 1/N — runs finish faster with more workers, so
//!   the churn window tracks the shrinking run.
//!
//! Each row is two `scenario::Scenario`s ([`scaling_scenarios`]): a
//! `Statics` one with counter-derived per-trial streams (`PerTrial` seed
//! mode keyed by `fold_in(cfg.seed, N)`) and a `Trace` one whose Poisson
//! churn runs on the same per-trial streams — every cell reproducible in
//! isolation, parallel trial pools bit-identical to serial, and the whole
//! derivation shared with `hcec run <scenario.toml>`.
//!
//! Reported metric is mean *computation* time (Fig. 2a's axis): BICEC's
//! K = 800 decode is N-independent and would swamp the scaling signal.

use crate::config::ExperimentConfig;
use crate::metrics::Table;
use crate::rng::fold_in;
use crate::scenario::{ElasticitySpec, Engine, Metric, Scenario, SchemeConfig, SeedMode};
use crate::sim::Reassign;
use crate::tas::Scheme;

/// Default worker-count grid for the scaling sweep.
pub const SCALING_NS: [usize; 4] = [40, 160, 640, 2560];

/// The (static, trace) scenario pair for one sweep row at fleet size `n`.
/// `events_per_node` is the expected number of elastic events per worker
/// slot within one trace horizon (fleet-wide rate = events_per_node · N /
/// horizon); the horizon tracks the faster run (~2 unstraggled CEC
/// sweeps).
pub fn scaling_scenarios(
    cfg: &ExperimentConfig,
    n: usize,
    events_per_node: f64,
    trials: usize,
) -> (Scenario, Scenario) {
    assert!(n >= cfg.s_cec, "sweep N={n} below S={}", cfg.s_cec);
    let seed_n = fold_in(cfg.seed, n as u64);
    let cost = cfg.cost_model();
    let schemes = SchemeConfig::paper_trio(cfg);
    let statics = Scenario::builder(&format!("scaling_static_n{n}"))
        .engine(Engine::Statics)
        .job(cfg.job)
        .fleet(n, n)
        .schemes(schemes.clone())
        .speed_model(cfg.speed_model())
        .cost(cost)
        .trials(trials)
        .seed(seed_n)
        .seed_mode(SeedMode::PerTrial)
        .build()
        .expect("valid static scaling scenario");
    let cec = crate::tas::Cec::new(cfg.k_cec, cfg.s_cec);
    let tau = cost.worker_time(cec.subtask_ops(cfg.job.u, cfg.job.w, cfg.job.v, n), 1.0);
    let horizon = 2.0 * cfg.s_cec as f64 * tau;
    let trace = Scenario::builder(&format!("scaling_trace_n{n}"))
        .engine(Engine::Trace)
        .job(cfg.job)
        .fleet(n, n)
        .schemes(schemes)
        .speed_model(cfg.speed_model())
        .cost(cost)
        .elasticity(ElasticitySpec::Churn {
            n_min: (n / 2).max(cfg.s_cec),
            n_initial: n,
            rate: events_per_node * n as f64 / horizon,
            horizon,
            reassign: Reassign::Identity,
        })
        .trials(trials)
        .seed(seed_n)
        .seed_mode(SeedMode::PerTrial)
        .build()
        .expect("valid trace scaling scenario");
    (statics, trace)
}

/// One row per N: paired static computation means and paired elastic-trace
/// computation means, plus CEC's transition waste and the failure count.
pub fn scaling_table(
    cfg: &ExperimentConfig,
    ns: &[usize],
    events_per_node: f64,
    trials: usize,
) -> Table {
    let mut t = Table::new(&[
        "N",
        "static_cec_s",
        "static_mlcec_%",
        "static_bicec_%",
        "trace_cec_s",
        "trace_mlcec_%",
        "trace_bicec_%",
        "cec_waste",
        "failures",
    ]);
    for &n in ns {
        let (st_sc, tr_sc) = scaling_scenarios(cfg, n, events_per_node, trials);
        let st = st_sc.run().expect("statics engine cannot fail");
        let tr = tr_sc.run().expect("trace engine reports failures per trial");
        let (sc, sm, sb) = (
            st.per_scheme[0].mean(Metric::Computation),
            st.per_scheme[1].mean(Metric::Computation),
            st.per_scheme[2].mean(Metric::Computation),
        );
        let tmean: Vec<f64> =
            tr.per_scheme.iter().map(|s| s.mean(Metric::Computation)).collect();
        let failures: usize = tr.per_scheme.iter().map(|s| s.failures()).sum();
        t.row(vec![
            n.to_string(),
            format!("{sc:.4}"),
            format!("{:+.1}", 100.0 * (sm - sc) / sc),
            format!("{:+.1}", 100.0 * (sb - sc) / sc),
            format!("{:.4}", tmean[0]),
            format!("{:+.1}", 100.0 * (tmean[1] - tmean[0]) / tmean[0]),
            format!("{:+.1}", 100.0 * (tmean[2] - tmean[0]) / tmean[0]),
            format!("{:.4}", tr.per_scheme[0].mean(Metric::TransitionWaste)),
            failures.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> ExperimentConfig {
        ExperimentConfig { trials: 5, ..Default::default() }
    }

    fn grab(table_render: &str, row: usize, col: usize) -> f64 {
        table_render
            .lines()
            .nth(2 + row) // skip header + rule
            .and_then(|l| l.split_whitespace().nth(col))
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("cell ({row}, {col}) of:\n{table_render}"))
    }

    #[test]
    fn scaling_table_static_time_shrinks_with_n() {
        let cfg = quick_cfg();
        let t = scaling_table(&cfg, &[40, 160], 1.0, 5);
        assert_eq!(t.n_rows(), 2);
        let r = t.render();
        let (t40, t160) = (grab(&r, 0, 1), grab(&r, 1, 1));
        assert!(
            t40 > 2.0 * t160,
            "4x the workers must shrink CEC computation well past 2x: {t40} vs {t160}"
        );
    }

    #[test]
    fn scaling_table_is_deterministic() {
        let cfg = quick_cfg();
        let a = scaling_table(&cfg, &[40, 160], 1.0, 4).render();
        let b = scaling_table(&cfg, &[40, 160], 1.0, 4).render();
        assert_eq!(a, b);
    }

    #[test]
    fn scaling_table_trace_survives_churn() {
        // Per-node churn of 1 event/horizon at N=40: some trials realloc,
        // and the sweep must not fail wholesale.
        let cfg = quick_cfg();
        let t = scaling_table(&cfg, &[40], 1.0, 5);
        let r = t.render();
        let failures = grab(&r, 0, 8);
        assert!(failures <= 3.0, "too many failed trials:\n{r}");
        let trace_cec = grab(&r, 0, 4);
        assert!(trace_cec.is_finite() && trace_cec > 0.0, "{r}");
    }

    #[test]
    fn scaling_scenarios_round_trip_through_toml() {
        let cfg = quick_cfg();
        let (st, tr) = scaling_scenarios(&cfg, 40, 1.0, 5);
        for sc in [st, tr] {
            let back = Scenario::from_toml(&sc.to_toml()).unwrap();
            assert_eq!(back.to_doc(), sc.to_doc(), "{}", sc.name);
        }
    }
}
