//! Runtime-dispatched SIMD kernels for the bulk GF(2^16) operations.
//!
//! Technique (the ISA-L split-table scheme, adapted to 16-bit symbols): a
//! multiply by a constant `c` is linear over GF(2), so it splits across the
//! four 4-bit nibbles of the operand —
//!
//! ```text
//!   c · x = c·(x & 0xF) ^ c·(x & 0xF0) ^ c·(x & 0xF00) ^ c·(x & 0xF000)
//! ```
//!
//! Each term has only 16 possible values, so per call we build four
//! 16-entry product tables from the log/exp tables (64 scalar multiplies),
//! split each into a low-byte and a high-byte plane, and then a single
//! byte-shuffle instruction (PSHUFB / `vqtbl1q_u8`) looks up 16 lanes at
//! once. The u16 lanes of a nibble-index vector hold the byte pair
//! `[v, 0x00]`, and table entry 0 is always 0 (`c · 0 = 0`), so the
//! shuffled planes recombine with a shift and XOR — no byte deinterleave.
//! The table-build cost amortises across the slice, which is why short
//! slices stay on the scalar oracle.
//!
//! `poly_eval_tile` and `dot` have no per-call constant to build tables
//! for; on AVX2 they instead gather straight from u32 copies of the
//! log/exp tables (`vpgatherdd`), eight lanes per step. XOR accumulation
//! is exact in any order, so every kernel here is bit-identical to its
//! scalar oracle in `gf.rs` — enforced by the property tests below and by
//! the forced-scalar CI arm (`HCEC_FORCE_SCALAR=1`).
//!
//! Dispatch: [`active_tier`] picks the best tier the CPU supports
//! (AVX2 > SSSE3 on x86-64, NEON on aarch64, scalar elsewhere), overridden
//! to scalar by `HCEC_FORCE_SCALAR`. The `*_tier` variants take an
//! explicit tier — benches and tests use them to pin a path regardless of
//! the process-global env knob.

use std::sync::OnceLock;

use super::gf::{self, Gf16};

/// A dispatchable kernel implementation level.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Tier {
    /// 256-bit split-table mul/addmul + gather poly_eval/dot (x86-64).
    Avx2,
    /// 128-bit split-table mul/addmul; poly_eval/dot stay scalar (x86-64).
    Ssse3,
    /// 128-bit split-table mul/addmul via TBL; poly_eval/dot stay scalar
    /// (aarch64).
    Neon,
    /// The verbatim original loops in `gf.rs` — the bit-identity oracle.
    Scalar,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Avx2 => "avx2",
            Tier::Ssse3 => "ssse3",
            Tier::Neon => "neon",
            Tier::Scalar => "scalar",
        }
    }
}

/// Whether `HCEC_FORCE_SCALAR` pins every dispatched kernel to the scalar
/// oracle. Read once; the knob is process-global.
pub fn force_scalar() -> bool {
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| match std::env::var("HCEC_FORCE_SCALAR") {
        Ok(v) => !matches!(v.trim(), "" | "0" | "false" | "off"),
        Err(_) => false,
    })
}

fn detect() -> Tier {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Tier::Avx2;
        }
        if std::arch::is_x86_feature_detected!("ssse3") {
            return Tier::Ssse3;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Tier::Neon;
        }
    }
    Tier::Scalar
}

/// Best tier this CPU supports (ignores `HCEC_FORCE_SCALAR`).
pub fn detected_tier() -> Tier {
    static TIER: OnceLock<Tier> = OnceLock::new();
    *TIER.get_or_init(detect)
}

/// The tier the dispatched entry points actually use.
pub fn active_tier() -> Tier {
    if force_scalar() {
        Tier::Scalar
    } else {
        detected_tier()
    }
}

/// Every tier runnable on this CPU, best first, always ending in Scalar.
/// Property tests iterate this so each compiled path is exercised.
pub fn supported_tiers() -> Vec<Tier> {
    let mut tiers = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            tiers.push(Tier::Avx2);
        }
        if std::arch::is_x86_feature_detected!("ssse3") {
            tiers.push(Tier::Ssse3);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            tiers.push(Tier::Neon);
        }
    }
    tiers.push(Tier::Scalar);
    tiers
}

/// Below this many symbols the per-call split-table build (64 scalar
/// multiplies) isn't amortised; the dispatchers stay scalar.
const MIN_SIMD_LEN: usize = 64;

/// Minimum tile width for the gather-based `poly_eval_tile` (one full
/// 8-lane group) and minimum length for the gather-based `dot`.
const MIN_GATHER_TILE: usize = 8;
const MIN_GATHER_LEN: usize = 32;

/// Split multiplication tables for one constant `c`: for nibble position
/// `i` and value `v`, entry `v` of table `i` is `c · (v << 4i)`, stored as
/// separate low/high byte planes so each plane is a 16-byte shuffle table.
struct SplitTables {
    lo: [[u8; 16]; 4],
    hi: [[u8; 16]; 4],
}

fn split_tables(c: Gf16) -> SplitTables {
    let mut t = SplitTables { lo: [[0u8; 16]; 4], hi: [[0u8; 16]; 4] };
    for nib in 0..4 {
        for v in 0..16u16 {
            let p = Gf16(v << (4 * nib)).mul(c).0;
            t.lo[nib][v as usize] = (p & 0xFF) as u8;
            t.hi[nib][v as usize] = (p >> 8) as u8;
        }
    }
    t
}

// ---- dispatched entry points (the public gf.rs wrappers land here) ------

/// `xs[i] *= c`, dispatched. See [`gf::mul_slice`].
pub fn mul_slice(c: Gf16, xs: &mut [Gf16]) {
    if c.0 <= 1 || xs.len() < MIN_SIMD_LEN {
        return gf::mul_slice_scalar(c, xs);
    }
    mul_slice_tier(active_tier(), c, xs)
}

/// `acc[i] ^= c * xs[i]`, dispatched. See [`gf::addmul_slice`].
pub fn addmul_slice(acc: &mut [Gf16], c: Gf16, xs: &[Gf16]) {
    assert_eq!(acc.len(), xs.len(), "addmul_slice length mismatch");
    if c.0 <= 1 || acc.len() < MIN_SIMD_LEN {
        return gf::addmul_slice_scalar(acc, c, xs);
    }
    addmul_slice_tier(active_tier(), acc, c, xs)
}

/// Tiled polynomial evaluation, dispatched. See [`gf::poly_eval_tile`].
pub fn poly_eval_tile(coeffs: &[Gf16], lpow: &[u16], tile: usize, out: &mut [Gf16]) {
    assert_eq!(out.len(), tile, "output/tile mismatch");
    assert_eq!(lpow.len(), coeffs.len() * tile, "power table/tile mismatch");
    if tile < MIN_GATHER_TILE {
        return gf::poly_eval_tile_scalar(coeffs, lpow, tile, out);
    }
    poly_eval_tile_tier(active_tier(), coeffs, lpow, tile, out)
}

/// Field inner product, dispatched. See [`gf::dot`].
pub fn dot(a: &[Gf16], b: &[Gf16]) -> Gf16 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    if a.len() < MIN_GATHER_LEN {
        return gf::dot_scalar(a, b);
    }
    dot_tier(active_tier(), a, b)
}

// ---- tier-explicit variants ---------------------------------------------
//
// No length thresholds: the kernels handle ragged tails internally, so
// tests can drive any length down any compiled path. A tier the CPU can't
// run (or that isn't compiled for this arch) silently falls back to the
// scalar oracle — callers iterate `supported_tiers()` to know what really
// runs.

/// [`mul_slice`] pinned to `tier`.
pub fn mul_slice_tier(tier: Tier, c: Gf16, xs: &mut [Gf16]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 if std::arch::is_x86_feature_detected!("avx2") => unsafe {
            x86::mul_slice_avx2(c, xs)
        },
        #[cfg(target_arch = "x86_64")]
        Tier::Ssse3 if std::arch::is_x86_feature_detected!("ssse3") => unsafe {
            x86::mul_slice_ssse3(c, xs)
        },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon if std::arch::is_aarch64_feature_detected!("neon") => unsafe {
            arm::mul_slice_neon(c, xs)
        },
        _ => gf::mul_slice_scalar(c, xs),
    }
}

/// [`addmul_slice`] pinned to `tier`.
pub fn addmul_slice_tier(tier: Tier, acc: &mut [Gf16], c: Gf16, xs: &[Gf16]) {
    assert_eq!(acc.len(), xs.len(), "addmul_slice length mismatch");
    match tier {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 if std::arch::is_x86_feature_detected!("avx2") => unsafe {
            x86::addmul_slice_avx2(acc, c, xs)
        },
        #[cfg(target_arch = "x86_64")]
        Tier::Ssse3 if std::arch::is_x86_feature_detected!("ssse3") => unsafe {
            x86::addmul_slice_ssse3(acc, c, xs)
        },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon if std::arch::is_aarch64_feature_detected!("neon") => unsafe {
            arm::addmul_slice_neon(acc, c, xs)
        },
        _ => gf::addmul_slice_scalar(acc, c, xs),
    }
}

/// [`poly_eval_tile`] pinned to `tier`. Only AVX2 has a vector path (the
/// gather kernel); every other tier is the scalar oracle.
pub fn poly_eval_tile_tier(tier: Tier, coeffs: &[Gf16], lpow: &[u16], tile: usize, out: &mut [Gf16]) {
    assert_eq!(out.len(), tile, "output/tile mismatch");
    assert_eq!(lpow.len(), coeffs.len() * tile, "power table/tile mismatch");
    match tier {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 if std::arch::is_x86_feature_detected!("avx2") => unsafe {
            x86::poly_eval_tile_avx2(coeffs, lpow, tile, out)
        },
        _ => gf::poly_eval_tile_scalar(coeffs, lpow, tile, out),
    }
}

/// [`dot`] pinned to `tier`. Only AVX2 has a vector path.
pub fn dot_tier(tier: Tier, a: &[Gf16], b: &[Gf16]) -> Gf16 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    match tier {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 if std::arch::is_x86_feature_detected!("avx2") => unsafe {
            x86::dot_avx2(a, b)
        },
        _ => gf::dot_scalar(a, b),
    }
}

// ---- x86-64 kernels ------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    use super::super::gf::{self, Gf16};
    use super::SplitTables;

    /// The eight 16-byte shuffle tables as registers, low/high plane per
    /// nibble, broadcast to both 128-bit lanes (PSHUFB shuffles within
    /// each lane independently, so both halves need the same table).
    #[target_feature(enable = "avx2")]
    unsafe fn load_tables_256(t: &SplitTables) -> [(__m256i, __m256i); 4] {
        let mut regs = [(_mm256_setzero_si256(), _mm256_setzero_si256()); 4];
        for nib in 0..4 {
            let lo = _mm_loadu_si128(t.lo[nib].as_ptr() as *const __m128i);
            let hi = _mm_loadu_si128(t.hi[nib].as_ptr() as *const __m128i);
            regs[nib] =
                (_mm256_broadcastsi128_si256(lo), _mm256_broadcastsi128_si256(hi));
        }
        regs
    }

    /// 16 parallel multiplies by the tables' constant.
    ///
    /// Each u16 lane of a nibble-index vector holds the bytes `[v, 0x00]`;
    /// PSHUFB reads `table[v]` into the low byte and `table[0] = 0` into
    /// the high byte, so the shuffled low plane IS the result's low byte,
    /// the shuffled high plane shifts up by 8, and the four nibble
    /// contributions XOR together.
    #[target_feature(enable = "avx2")]
    unsafe fn mul16_avx2(regs: &[(__m256i, __m256i); 4], x: __m256i) -> __m256i {
        let mask = _mm256_set1_epi16(0x000F);
        let idx = [
            _mm256_and_si256(x, mask),
            _mm256_and_si256(_mm256_srli_epi16::<4>(x), mask),
            _mm256_and_si256(_mm256_srli_epi16::<8>(x), mask),
            _mm256_srli_epi16::<12>(x),
        ];
        let mut acc = _mm256_setzero_si256();
        for nib in 0..4 {
            let lo = _mm256_shuffle_epi8(regs[nib].0, idx[nib]);
            let hi = _mm256_shuffle_epi8(regs[nib].1, idx[nib]);
            acc = _mm256_xor_si256(acc, _mm256_xor_si256(lo, _mm256_slli_epi16::<8>(hi)));
        }
        acc
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mul_slice_avx2(c: Gf16, xs: &mut [Gf16]) {
        let regs = load_tables_256(&super::split_tables(c));
        let mut chunks = xs.chunks_exact_mut(16);
        for ch in &mut chunks {
            let p = ch.as_mut_ptr() as *mut __m256i;
            let v = _mm256_loadu_si256(p as *const __m256i);
            _mm256_storeu_si256(p, mul16_avx2(&regs, v));
        }
        gf::mul_slice_scalar(c, chunks.into_remainder());
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn addmul_slice_avx2(acc: &mut [Gf16], c: Gf16, xs: &[Gf16]) {
        let regs = load_tables_256(&super::split_tables(c));
        let mut a_chunks = acc.chunks_exact_mut(16);
        let mut x_chunks = xs.chunks_exact(16);
        for (a, x) in (&mut a_chunks).zip(&mut x_chunks) {
            let xv = _mm256_loadu_si256(x.as_ptr() as *const __m256i);
            let ap = a.as_mut_ptr() as *mut __m256i;
            let av = _mm256_loadu_si256(ap as *const __m256i);
            _mm256_storeu_si256(ap, _mm256_xor_si256(av, mul16_avx2(&regs, xv)));
        }
        gf::addmul_slice_scalar(a_chunks.into_remainder(), c, x_chunks.remainder());
    }

    #[target_feature(enable = "ssse3")]
    unsafe fn load_tables_128(t: &SplitTables) -> [(__m128i, __m128i); 4] {
        let mut regs = [(_mm_setzero_si128(), _mm_setzero_si128()); 4];
        for nib in 0..4 {
            regs[nib] = (
                _mm_loadu_si128(t.lo[nib].as_ptr() as *const __m128i),
                _mm_loadu_si128(t.hi[nib].as_ptr() as *const __m128i),
            );
        }
        regs
    }

    /// 8 parallel multiplies — the 128-bit version of [`mul16_avx2`].
    #[target_feature(enable = "ssse3")]
    unsafe fn mul8_ssse3(regs: &[(__m128i, __m128i); 4], x: __m128i) -> __m128i {
        let mask = _mm_set1_epi16(0x000F);
        let idx = [
            _mm_and_si128(x, mask),
            _mm_and_si128(_mm_srli_epi16::<4>(x), mask),
            _mm_and_si128(_mm_srli_epi16::<8>(x), mask),
            _mm_srli_epi16::<12>(x),
        ];
        let mut acc = _mm_setzero_si128();
        for nib in 0..4 {
            let lo = _mm_shuffle_epi8(regs[nib].0, idx[nib]);
            let hi = _mm_shuffle_epi8(regs[nib].1, idx[nib]);
            acc = _mm_xor_si128(acc, _mm_xor_si128(lo, _mm_slli_epi16::<8>(hi)));
        }
        acc
    }

    #[target_feature(enable = "ssse3")]
    pub(super) unsafe fn mul_slice_ssse3(c: Gf16, xs: &mut [Gf16]) {
        let regs = load_tables_128(&super::split_tables(c));
        let mut chunks = xs.chunks_exact_mut(8);
        for ch in &mut chunks {
            let p = ch.as_mut_ptr() as *mut __m128i;
            let v = _mm_loadu_si128(p as *const __m128i);
            _mm_storeu_si128(p, mul8_ssse3(&regs, v));
        }
        gf::mul_slice_scalar(c, chunks.into_remainder());
    }

    #[target_feature(enable = "ssse3")]
    pub(super) unsafe fn addmul_slice_ssse3(acc: &mut [Gf16], c: Gf16, xs: &[Gf16]) {
        let regs = load_tables_128(&super::split_tables(c));
        let mut a_chunks = acc.chunks_exact_mut(8);
        let mut x_chunks = xs.chunks_exact(8);
        for (a, x) in (&mut a_chunks).zip(&mut x_chunks) {
            let xv = _mm_loadu_si128(x.as_ptr() as *const __m128i);
            let ap = a.as_mut_ptr() as *mut __m128i;
            let av = _mm_loadu_si128(ap as *const __m128i);
            _mm_storeu_si128(ap, _mm_xor_si128(av, mul8_ssse3(&regs, xv)));
        }
        gf::addmul_slice_scalar(a_chunks.into_remainder(), c, x_chunks.remainder());
    }

    // ---- gather kernels (AVX2 only) -------------------------------------

    /// u32 widening of the doubled exp table for `vpgatherdd` (the gather
    /// reads 32-bit elements). Built once, ~512 KiB.
    fn exp32() -> &'static [u32] {
        static T: std::sync::OnceLock<Vec<u32>> = std::sync::OnceLock::new();
        T.get_or_init(|| gf::exp_table().iter().map(|&v| v as u32).collect())
    }

    /// u32 widening of the log table. Entry 0 is 0 (a real, in-bounds
    /// index), so gathers over zero lanes stay safe and get masked after.
    fn log32() -> &'static [u32] {
        static T: std::sync::OnceLock<Vec<u32>> = std::sync::OnceLock::new();
        T.get_or_init(|| gf::log_table().iter().map(|&v| v as u32).collect())
    }

    /// Gather-based tile evaluation, 8 shares per vector: per (l, group)
    /// the indices `log c_l + log x_t^l` are formed in u32 lanes and one
    /// gather reads the doubled exp table (index < 2·(2^16 − 1), always in
    /// bounds). XOR accumulation is exact in any order, so the result is
    /// bit-identical to the scalar loop. Columns past the last full group
    /// run the same arithmetic scalar.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn poly_eval_tile_avx2(
        coeffs: &[Gf16],
        lpow: &[u16],
        tile: usize,
        out: &mut [Gf16],
    ) {
        let log = gf::log_table();
        let base = exp32().as_ptr() as *const i32;
        let groups = tile / 8;
        for grp in 0..groups {
            let t0 = grp * 8;
            let mut acc = _mm256_setzero_si256();
            for (l, c) in coeffs.iter().enumerate() {
                if c.0 == 0 {
                    continue;
                }
                let lc = _mm256_set1_epi32(log[c.0 as usize] as i32);
                let lp =
                    _mm_loadu_si128(lpow.as_ptr().add(l * tile + t0) as *const __m128i);
                let idx = _mm256_add_epi32(_mm256_cvtepu16_epi32(lp), lc);
                acc = _mm256_xor_si256(acc, _mm256_i32gather_epi32::<4>(base, idx));
            }
            let mut lanes = [0u32; 8];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            for (t, &v) in lanes.iter().enumerate() {
                out[t0 + t].0 ^= v as u16;
            }
        }
        let rem0 = groups * 8;
        if rem0 < tile {
            let exp = gf::exp_table();
            for (l, c) in coeffs.iter().enumerate() {
                if c.0 == 0 {
                    continue;
                }
                let lc = log[c.0 as usize] as usize;
                let row = &lpow[l * tile..(l + 1) * tile];
                for t in rem0..tile {
                    out[t].0 ^= exp[lc + row[t] as usize];
                }
            }
        }
    }

    /// Gather-based inner product, 8 element pairs per step. Lanes where
    /// either operand is zero contribute nothing: the gathers still run
    /// (`log[0]` is a real in-bounds entry) and the bogus products are
    /// masked off before the XOR.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_avx2(a: &[Gf16], b: &[Gf16]) -> Gf16 {
        debug_assert_eq!(a.len(), b.len());
        let lbase = log32().as_ptr() as *const i32;
        let ebase = exp32().as_ptr() as *const i32;
        let zero = _mm256_setzero_si256();
        let mut acc = zero;
        let n8 = a.len() - a.len() % 8;
        let mut i = 0;
        while i < n8 {
            let av = _mm256_cvtepu16_epi32(_mm_loadu_si128(
                a.as_ptr().add(i) as *const __m128i
            ));
            let bv = _mm256_cvtepu16_epi32(_mm_loadu_si128(
                b.as_ptr().add(i) as *const __m128i
            ));
            let skip = _mm256_or_si256(
                _mm256_cmpeq_epi32(av, zero),
                _mm256_cmpeq_epi32(bv, zero),
            );
            let la = _mm256_i32gather_epi32::<4>(lbase, av);
            let lb = _mm256_i32gather_epi32::<4>(lbase, bv);
            let prod = _mm256_i32gather_epi32::<4>(ebase, _mm256_add_epi32(la, lb));
            acc = _mm256_xor_si256(acc, _mm256_andnot_si256(skip, prod));
            i += 8;
        }
        let mut lanes = [0u32; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut r = lanes.iter().fold(0u16, |s, &v| s ^ v as u16);
        r ^= gf::dot_scalar(&a[n8..], &b[n8..]).0;
        Gf16(r)
    }
}

// ---- aarch64 kernels -----------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use core::arch::aarch64::*;

    use super::super::gf::{self, Gf16};
    use super::SplitTables;

    struct Tables128 {
        lo: [uint8x16_t; 4],
        hi: [uint8x16_t; 4],
    }

    #[target_feature(enable = "neon")]
    unsafe fn load_tables(t: &SplitTables) -> Tables128 {
        let mut lo = [vdupq_n_u8(0); 4];
        let mut hi = [vdupq_n_u8(0); 4];
        for nib in 0..4 {
            lo[nib] = vld1q_u8(t.lo[nib].as_ptr());
            hi[nib] = vld1q_u8(t.hi[nib].as_ptr());
        }
        Tables128 { lo, hi }
    }

    /// 8 parallel multiplies; the same `[v, 0x00]` byte-pair trick as the
    /// x86 path (TBL reads `table[0] = 0` for the zero high bytes).
    #[target_feature(enable = "neon")]
    unsafe fn mul8_neon(t: &Tables128, x: uint16x8_t) -> uint16x8_t {
        let mask = vdupq_n_u16(0x000F);
        let idx = [
            vandq_u16(x, mask),
            vandq_u16(vshrq_n_u16::<4>(x), mask),
            vandq_u16(vshrq_n_u16::<8>(x), mask),
            vshrq_n_u16::<12>(x),
        ];
        let mut acc = vdupq_n_u16(0);
        for nib in 0..4 {
            let iv = vreinterpretq_u8_u16(idx[nib]);
            let lo = vreinterpretq_u16_u8(vqtbl1q_u8(t.lo[nib], iv));
            let hi = vreinterpretq_u16_u8(vqtbl1q_u8(t.hi[nib], iv));
            acc = veorq_u16(acc, veorq_u16(lo, vshlq_n_u16::<8>(hi)));
        }
        acc
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn mul_slice_neon(c: Gf16, xs: &mut [Gf16]) {
        let t = load_tables(&super::split_tables(c));
        let mut chunks = xs.chunks_exact_mut(8);
        for ch in &mut chunks {
            let p = ch.as_mut_ptr() as *mut u16;
            vst1q_u16(p, mul8_neon(&t, vld1q_u16(p as *const u16)));
        }
        gf::mul_slice_scalar(c, chunks.into_remainder());
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn addmul_slice_neon(acc: &mut [Gf16], c: Gf16, xs: &[Gf16]) {
        let t = load_tables(&super::split_tables(c));
        let mut a_chunks = acc.chunks_exact_mut(8);
        let mut x_chunks = xs.chunks_exact(8);
        for (a, x) in (&mut a_chunks).zip(&mut x_chunks) {
            let xv = vld1q_u16(x.as_ptr() as *const u16);
            let ap = a.as_mut_ptr() as *mut u16;
            let av = vld1q_u16(ap as *const u16);
            vst1q_u16(ap, veorq_u16(av, mul8_neon(&t, xv)));
        }
        gf::addmul_slice_scalar(a_chunks.into_remainder(), c, x_chunks.remainder());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    /// Random symbol stream with a forced sprinkling of zeros (mirrors the
    /// gf.rs oracle tests), so every kernel's zero handling is exercised.
    fn stream_with_zeros(g: &mut crate::prop::Gen, len: usize) -> Vec<Gf16> {
        (0..len)
            .map(|i| {
                if i % 7 == 3 || g.u64() % 5 == 0 {
                    Gf16::ZERO
                } else {
                    Gf16(g.u64() as u16)
                }
            })
            .collect()
    }

    /// Random constant including the special cases 0 and 1.
    fn random_constant(g: &mut crate::prop::Gen) -> Gf16 {
        match g.u64() % 4 {
            0 => Gf16::ZERO,
            1 => Gf16::ONE,
            _ => Gf16(g.u64() as u16),
        }
    }

    #[test]
    fn split_tables_cover_every_nibble_product() {
        prop::check(40, |g| {
            let c = Gf16(g.u64() as u16);
            let t = split_tables(c);
            for nib in 0..4 {
                for v in 0..16u16 {
                    let want = Gf16(v << (4 * nib)).mul(c).0;
                    let got = (t.lo[nib][v as usize] as u16)
                        | ((t.hi[nib][v as usize] as u16) << 8);
                    if got != want {
                        return Err(format!(
                            "table mismatch c={:#x} nib={nib} v={v}: got {got:#x} want {want:#x}",
                            c.0
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn every_supported_tier_mul_slice_is_bit_identical() {
        for tier in supported_tiers() {
            prop::check(60, |g| {
                // Lengths cross vector widths and ragged tails (len % 16 != 0).
                let len = g.usize_in(0, 200);
                let xs = stream_with_zeros(g, len);
                let c = random_constant(g);
                let mut want = xs.clone();
                gf::mul_slice_scalar(c, &mut want);
                let mut got = xs;
                mul_slice_tier(tier, c, &mut got);
                if got != want {
                    return Err(format!(
                        "tier {} mul_slice diverged (len={len}, c={:#x})",
                        tier.name(),
                        c.0
                    ));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn every_supported_tier_addmul_slice_is_bit_identical() {
        for tier in supported_tiers() {
            prop::check(60, |g| {
                let len = g.usize_in(0, 200);
                let xs = stream_with_zeros(g, len);
                let acc0 = stream_with_zeros(g, len);
                let c = random_constant(g);
                let mut want = acc0.clone();
                gf::addmul_slice_scalar(&mut want, c, &xs);
                let mut got = acc0;
                addmul_slice_tier(tier, &mut got, c, &xs);
                if got != want {
                    return Err(format!(
                        "tier {} addmul_slice diverged (len={len}, c={:#x})",
                        tier.name(),
                        c.0
                    ));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn every_supported_tier_poly_eval_tile_is_bit_identical() {
        for tier in supported_tiers() {
            prop::check(40, |g| {
                let k = g.usize_in(1, 40);
                // Tiles cross the 8-lane gather groups plus ragged tails.
                let tile = g.usize_in(1, 37);
                let points: Vec<Gf16> =
                    (0..tile).map(|_| Gf16((g.u64() as u16).max(1))).collect();
                let mut lpow = vec![0u16; k * tile];
                for (t, &x) in points.iter().enumerate() {
                    let lx = gf::discrete_log(x) as u32;
                    let mut cur = 0u32;
                    for l in 0..k {
                        lpow[l * tile + t] = cur as u16;
                        cur += lx;
                        if cur >= 65535 {
                            cur -= 65535;
                        }
                    }
                }
                let coeffs = stream_with_zeros(g, k);
                let mut want = vec![Gf16::ZERO; tile];
                gf::poly_eval_tile_scalar(&coeffs, &lpow, tile, &mut want);
                let mut got = vec![Gf16::ZERO; tile];
                poly_eval_tile_tier(tier, &coeffs, &lpow, tile, &mut got);
                if got != want {
                    return Err(format!(
                        "tier {} poly_eval_tile diverged (k={k}, tile={tile})",
                        tier.name()
                    ));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn every_supported_tier_dot_is_bit_identical() {
        for tier in supported_tiers() {
            prop::check(60, |g| {
                let len = g.usize_in(0, 120);
                let a = stream_with_zeros(g, len);
                let b = stream_with_zeros(g, len);
                let want = gf::dot_scalar(&a, &b);
                let got = dot_tier(tier, &a, &b);
                if got != want {
                    return Err(format!(
                        "tier {} dot diverged (len={len}): got {:#x} want {:#x}",
                        tier.name(),
                        got.0,
                        want.0
                    ));
                }
                Ok(())
            });
        }
    }

    #[test]
    fn dispatched_wrappers_match_scalar_on_large_buffers() {
        // Above the length thresholds the public entry points take the
        // active tier; whatever that is, results must match the oracle
        // bitwise (under HCEC_FORCE_SCALAR=1 this trivially compares the
        // oracle with itself — both CI arms run it).
        let xs: Vec<Gf16> = (0..1000)
            .map(|i| Gf16(((i as u64 * 2654435761) % 65536) as u16))
            .collect();
        let ys: Vec<Gf16> = (0..1000)
            .map(|i| Gf16(((i as u64 * 40503 + 7) % 65536) as u16))
            .collect();
        let c = Gf16(0x1234);

        let mut want = xs.clone();
        gf::mul_slice_scalar(c, &mut want);
        let mut got = xs.clone();
        mul_slice(c, &mut got);
        assert_eq!(got, want, "mul_slice dispatch diverged");

        let mut want = ys.clone();
        gf::addmul_slice_scalar(&mut want, c, &xs);
        let mut got = ys.clone();
        addmul_slice(&mut got, c, &xs);
        assert_eq!(got, want, "addmul_slice dispatch diverged");

        assert_eq!(
            dot(&xs, &ys),
            gf::dot_scalar(&xs, &ys),
            "dot dispatch diverged"
        );
    }

    #[test]
    fn forced_scalar_env_routes_to_scalar_tier() {
        // Valid under both CI arms: with HCEC_FORCE_SCALAR=1 the active
        // tier must be Scalar; with the knob unset (or explicitly off) the
        // active tier is whatever the CPU detection found.
        match std::env::var("HCEC_FORCE_SCALAR").ok().as_deref().map(str::trim) {
            Some("1") | Some("true") | Some("on") => {
                assert!(force_scalar());
                assert_eq!(active_tier(), Tier::Scalar);
            }
            None | Some("") | Some("0") | Some("false") | Some("off") => {
                assert!(!force_scalar());
                assert_eq!(active_tier(), detected_tier());
            }
            _ => {} // exotic spellings: parse covered by force_scalar itself
        }
    }

    #[test]
    fn active_tier_is_among_supported() {
        let tiers = supported_tiers();
        assert!(tiers.contains(&active_tier()));
        assert_eq!(*tiers.last().unwrap(), Tier::Scalar, "scalar always runnable");
    }
}
