//! Small LRU of inverted decode matrices, shared by the exact (GF) and
//! real-valued codecs. Keyed by the ordered survivor-index subset: the
//! master decodes many symbol streams / Monte-Carlo trials against the
//! same completed worker set, and re-running the O(k³) inversion per
//! decode would dominate at BICEC's k = 800.

use std::collections::HashMap;
use std::sync::Arc;

#[derive(Debug)]
pub(crate) struct LruCache<V> {
    capacity: usize,
    /// Monotone access stamp for least-recently-used eviction.
    stamp: u64,
    entries: HashMap<Vec<usize>, (u64, Arc<V>)>,
    hits: u64,
    misses: u64,
}

impl<V> LruCache<V> {
    pub fn new(capacity: usize) -> Self {
        Self { capacity, stamp: 0, entries: HashMap::new(), hits: 0, misses: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    pub fn get(&mut self, key: &[usize]) -> Option<Arc<V>> {
        self.stamp += 1;
        let stamp = self.stamp;
        match self.entries.get_mut(key) {
            Some((last, value)) => {
                *last = stamp;
                self.hits += 1;
                Some(value.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn insert(&mut self, key: Vec<usize>, value: Arc<V>) {
        if self.capacity == 0 {
            return;
        }
        self.stamp += 1;
        self.entries.insert(key, (self.stamp, value));
        while self.entries.len() > self.capacity {
            let oldest = self
                .entries
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone())
                .expect("nonempty while over capacity");
            self.entries.remove(&oldest);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recently_used() {
        let mut c: LruCache<u32> = LruCache::new(2);
        c.insert(vec![1], Arc::new(10));
        c.insert(vec![2], Arc::new(20));
        assert!(c.get(&[1]).is_some()); // refresh 1
        c.insert(vec![3], Arc::new(30)); // evicts 2
        assert!(c.get(&[2]).is_none());
        assert!(c.get(&[1]).is_some());
        assert!(c.get(&[3]).is_some());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn capacity_zero_never_stores() {
        let mut c: LruCache<u32> = LruCache::new(0);
        c.insert(vec![1], Arc::new(10));
        assert!(c.get(&[1]).is_none());
        assert_eq!(c.len(), 0);
        let (hits, misses) = c.stats();
        assert_eq!(hits, 0);
        assert_eq!(misses, 1);
    }
}
