//! GF(2^16) arithmetic with log/antilog tables.
//!
//! Substrate for the exact Reed–Solomon path (`rs.rs`): BICEC's (800, 3200)
//! code cannot be decoded in floating point, so payloads are quantised to
//! u16 fixed point and coded in an exact field.
//!
//! Field: GF(2^16) = GF(2)[x] / (x^16 + x^12 + x^3 + x + 1)  (0x1100B,
//! a standard primitive polynomial).
//!
//! Besides scalar `Gf16` arithmetic, this module provides the bulk slice
//! kernels the codec hot paths are built on (`mul_slice`, `addmul_slice`,
//! `dot`, `poly_eval_tile`). The public names are thin wrappers that route
//! through [`super::simd`]'s runtime dispatch (AVX2 / SSSE3 / NEON
//! split-table and gather kernels); the original scalar loops are kept
//! verbatim as `*_scalar` — the bit-identity oracles every SIMD path is
//! tested against, and the forced path when `HCEC_FORCE_SCALAR=1`. In the
//! scalar loops the table references and the constant's log are hoisted out
//! of the loop and the per-element zero test reduces to one branch, which
//! is what makes the (800, 3200) encode/decode throughput-bound rather
//! than lookup-latency-bound.

const POLY: u32 = 0x1100B;
const ORDER: usize = 1 << 16;

/// Precomputed log/exp tables (built once, lazily).
struct Tables {
    exp: Vec<u16>, // exp[i] = g^i, length 2*(ORDER-1) to skip a mod
    log: Vec<u16>, // log[x] for x != 0
}

fn tables() -> &'static Tables {
    use std::sync::OnceLock;
    static TABLES: OnceLock<Tables> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut exp = vec![0u16; 2 * (ORDER - 1)];
        let mut log = vec![0u16; ORDER];
        let mut x: u32 = 1;
        for i in 0..ORDER - 1 {
            exp[i] = x as u16;
            log[x as usize] = i as u16;
            x <<= 1;
            if x & (1 << 16) != 0 {
                x ^= POLY;
            }
        }
        for i in 0..ORDER - 1 {
            exp[ORDER - 1 + i] = exp[i];
        }
        Tables { exp, log }
    })
}

/// The doubled exp table (`exp[i] = g^i` for `i < 2 * (2^16 - 1)`), exposed
/// for the SIMD gather kernels in [`super::simd`].
pub(crate) fn exp_table() -> &'static [u16] {
    &tables().exp
}

/// The log table (`log[x]` for nonzero `x`; entry 0 is unused), exposed for
/// the SIMD gather kernels in [`super::simd`].
pub(crate) fn log_table() -> &'static [u16] {
    &tables().log
}

/// An element of GF(2^16).
///
/// `repr(transparent)`: guaranteed to have exactly the layout of `u16`, so
/// the SIMD kernels may reinterpret `&[Gf16]` buffers as raw `u16` lanes.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default, Hash)]
#[repr(transparent)]
pub struct Gf16(pub u16);

impl Gf16 {
    pub const ZERO: Gf16 = Gf16(0);
    pub const ONE: Gf16 = Gf16(1);

    #[inline]
    pub fn add(self, rhs: Gf16) -> Gf16 {
        Gf16(self.0 ^ rhs.0)
    }

    // Subtraction == addition in characteristic 2.
    #[inline]
    pub fn sub(self, rhs: Gf16) -> Gf16 {
        self.add(rhs)
    }

    #[inline]
    pub fn mul(self, rhs: Gf16) -> Gf16 {
        if self.0 == 0 || rhs.0 == 0 {
            return Gf16::ZERO;
        }
        let t = tables();
        let idx = t.log[self.0 as usize] as usize + t.log[rhs.0 as usize] as usize;
        Gf16(t.exp[idx])
    }

    #[inline]
    pub fn inv(self) -> Gf16 {
        assert!(self.0 != 0, "inverse of zero in GF(2^16)");
        let t = tables();
        let l = t.log[self.0 as usize] as usize;
        Gf16(t.exp[(ORDER - 1 - l) % (ORDER - 1)])
    }

    #[inline]
    pub fn div(self, rhs: Gf16) -> Gf16 {
        self.mul(rhs.inv())
    }

    pub fn pow(self, mut e: u64) -> Gf16 {
        if self.0 == 0 {
            return if e == 0 { Gf16::ONE } else { Gf16::ZERO };
        }
        let mut base = self;
        let mut acc = Gf16::ONE;
        while e > 0 {
            if e & 1 == 1 {
                acc = acc.mul(base);
            }
            base = base.mul(base);
            e >>= 1;
        }
        acc
    }

    /// The generator alpha (x).
    pub fn alpha() -> Gf16 {
        Gf16(2)
    }
}

/// `xs[i] *= c` for every element, in place.
///
/// Dispatched: long slices ride the split-table SIMD kernel for the
/// detected tier ([`super::simd::active_tier`]); short slices and
/// `HCEC_FORCE_SCALAR=1` take [`mul_slice_scalar`]. Bit-identical either
/// way.
pub fn mul_slice(c: Gf16, xs: &mut [Gf16]) {
    super::simd::mul_slice(c, xs)
}

/// Scalar oracle for [`mul_slice`] (the original loop, kept verbatim).
///
/// Zero-branch lifted: `c == 0` zero-fills without touching the tables;
/// otherwise the tables and `log c` are read once and the loop body is a
/// single lookup chain per nonzero element.
pub fn mul_slice_scalar(c: Gf16, xs: &mut [Gf16]) {
    if c.0 == 0 {
        xs.fill(Gf16::ZERO);
        return;
    }
    if c.0 == 1 {
        return;
    }
    let t = tables();
    let lc = t.log[c.0 as usize] as usize;
    for x in xs.iter_mut() {
        if x.0 != 0 {
            *x = Gf16(t.exp[lc + t.log[x.0 as usize] as usize]);
        }
    }
}

/// `acc[i] += c * xs[i]` (addition is XOR). The codec combine kernel.
///
/// Dispatched like [`mul_slice`]; panics if the slices have different
/// lengths.
pub fn addmul_slice(acc: &mut [Gf16], c: Gf16, xs: &[Gf16]) {
    super::simd::addmul_slice(acc, c, xs)
}

/// Scalar oracle for [`addmul_slice`] (the original loop, kept verbatim).
///
/// Panics if the slices have different lengths.
pub fn addmul_slice_scalar(acc: &mut [Gf16], c: Gf16, xs: &[Gf16]) {
    assert_eq!(acc.len(), xs.len(), "addmul_slice length mismatch");
    if c.0 == 0 {
        return;
    }
    let t = tables();
    if c.0 == 1 {
        for (a, x) in acc.iter_mut().zip(xs) {
            a.0 ^= x.0;
        }
        return;
    }
    let lc = t.log[c.0 as usize] as usize;
    for (a, x) in acc.iter_mut().zip(xs) {
        if x.0 != 0 {
            a.0 ^= t.exp[lc + t.log[x.0 as usize] as usize];
        }
    }
}

/// Discrete log base alpha of a nonzero element. Panics on zero (zero has
/// no log); used to build log-domain power tables for the tiled encoder.
#[inline]
pub fn discrete_log(x: Gf16) -> u16 {
    assert!(x.0 != 0, "discrete log of zero in GF(2^16)");
    tables().log[x.0 as usize]
}

/// Tiled polynomial evaluation — the multi-share encode kernel.
///
/// `lpow[l * tile + t]` must hold the discrete log of `x_t^l` for the
/// tile's (nonzero) evaluation points `x_0 .. x_{tile-1}`; `out[t]`
/// accumulates `Σ_l coeffs[l] · x_t^l` (XOR sum) on top of its current
/// contents, so callers zero `out` first. The coefficient's log is looked
/// up once per `l` and shared by the whole tile: evaluating `tile` shares
/// makes ONE pass over the coefficients where per-share [`dot`] calls
/// make `tile`, and the per-element work drops to a single exp-table read.
///
/// Dispatched: wide tiles ride the AVX2 gather kernel
/// ([`super::simd::poly_eval_tile`]); narrow tiles, non-AVX2 tiers, and
/// `HCEC_FORCE_SCALAR=1` take [`poly_eval_tile_scalar`].
pub fn poly_eval_tile(coeffs: &[Gf16], lpow: &[u16], tile: usize, out: &mut [Gf16]) {
    super::simd::poly_eval_tile(coeffs, lpow, tile, out)
}

/// Scalar oracle for [`poly_eval_tile`] (the original loop, kept verbatim).
pub fn poly_eval_tile_scalar(coeffs: &[Gf16], lpow: &[u16], tile: usize, out: &mut [Gf16]) {
    assert_eq!(out.len(), tile, "output/tile mismatch");
    assert_eq!(lpow.len(), coeffs.len() * tile, "power table/tile mismatch");
    let t = tables();
    for (l, c) in coeffs.iter().enumerate() {
        if c.0 == 0 {
            continue;
        }
        let lc = t.log[c.0 as usize] as usize;
        let row = &lpow[l * tile..(l + 1) * tile];
        for (o, &lp) in out.iter_mut().zip(row) {
            // lc + lp < 2 * (2^16 - 1): covered by the doubled exp table.
            o.0 ^= t.exp[lc + lp as usize];
        }
    }
}

/// Inner product `Σ_i a[i] · b[i]` over the field (sum is XOR).
///
/// Dispatched: long inputs ride the AVX2 gather kernel (XOR accumulation
/// is order-independent, so the result is exact); otherwise
/// [`dot_scalar`]. Panics if the slices have different lengths.
pub fn dot(a: &[Gf16], b: &[Gf16]) -> Gf16 {
    super::simd::dot(a, b)
}

/// Scalar oracle for [`dot`] (the original loop, kept verbatim).
///
/// Panics if the slices have different lengths.
pub fn dot_scalar(a: &[Gf16], b: &[Gf16]) -> Gf16 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    let t = tables();
    let mut acc: u16 = 0;
    for (x, y) in a.iter().zip(b) {
        if x.0 != 0 && y.0 != 0 {
            acc ^= t.exp[t.log[x.0 as usize] as usize + t.log[y.0 as usize] as usize];
        }
    }
    Gf16(acc)
}

/// `Σ_l coeffs[l] · x^l` — the dot product against a constant power row,
/// evaluated through the tiled log-domain path ([`poly_eval_tile`]'s inner
/// loop with a tile of one): the powers are never materialised, their logs
/// walk an arithmetic progression mod 2^16 - 1, and each nonzero
/// coefficient costs one log read and one exp read. This is the shared
/// inner loop of single-share encode and per-point decode checks —
/// previously `dot` against an explicit `powers` vector rebuilt per call.
pub fn dot_power_row(coeffs: &[Gf16], x: Gf16) -> Gf16 {
    if x.0 == 0 {
        // x^0 = 1, x^l = 0 for l > 0: only the constant term survives.
        return coeffs.first().copied().unwrap_or(Gf16::ZERO);
    }
    let t = tables();
    let lx = t.log[x.0 as usize] as u32;
    let mut lp = 0u32; // log(x^l), kept reduced mod 2^16 - 1
    let mut acc: u16 = 0;
    for c in coeffs {
        if c.0 != 0 {
            // lc + lp < 2 * (2^16 - 1): covered by the doubled exp table.
            acc ^= t.exp[t.log[c.0 as usize] as usize + lp as usize];
        }
        lp += lx;
        if lp >= 65535 {
            lp -= 65535;
        }
    }
    Gf16(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn additive_identity_and_self_inverse() {
        let a = Gf16(0x1234);
        assert_eq!(a.add(Gf16::ZERO), a);
        assert_eq!(a.add(a), Gf16::ZERO);
    }

    #[test]
    fn multiplicative_identity_and_inverse() {
        for v in [1u16, 2, 3, 0xFFFF, 0x8001, 257] {
            let a = Gf16(v);
            assert_eq!(a.mul(Gf16::ONE), a);
            assert_eq!(a.mul(a.inv()), Gf16::ONE, "v={v:#x}");
        }
    }

    #[test]
    fn alpha_has_full_order() {
        // alpha^(2^16 - 1) = 1 but alpha^m != 1 for the proper divisors'
        // quotient checks (65535 = 3 * 5 * 17 * 257).
        let a = Gf16::alpha();
        assert_eq!(a.pow(65535), Gf16::ONE);
        for d in [3u64, 5, 17, 257] {
            assert_ne!(a.pow(65535 / d), Gf16::ONE, "order divides 65535/{d}");
        }
    }

    #[test]
    fn prop_field_axioms() {
        prop::check(200, |g| {
            let a = Gf16(g.u64() as u16);
            let b = Gf16(g.u64() as u16);
            let c = Gf16(g.u64() as u16);
            // commutativity
            if a.mul(b) != b.mul(a) {
                return Err("mul not commutative".into());
            }
            // associativity
            if a.mul(b).mul(c) != a.mul(b.mul(c)) {
                return Err("mul not associative".into());
            }
            // distributivity
            if a.mul(b.add(c)) != a.mul(b).add(a.mul(c)) {
                return Err("not distributive".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_division_round_trip() {
        prop::check(200, |g| {
            let a = Gf16(g.u64() as u16);
            let b = Gf16((g.u64() as u16).max(1));
            if a.div(b).mul(b) != a {
                return Err(format!("(a/b)*b != a for a={:#x} b={:#x}", a.0, b.0));
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "inverse of zero")]
    fn zero_has_no_inverse() {
        let _ = Gf16::ZERO.inv();
    }

    /// Random symbol stream with a forced sprinkling of zeros, so the bulk
    /// kernels' lifted zero branches are always exercised.
    fn stream_with_zeros(g: &mut crate::prop::Gen, len: usize) -> Vec<Gf16> {
        (0..len)
            .map(|i| {
                if i % 7 == 3 || g.u64() % 5 == 0 {
                    Gf16::ZERO
                } else {
                    Gf16(g.u64() as u16)
                }
            })
            .collect()
    }

    #[test]
    fn prop_mul_slice_matches_scalar_mul() {
        prop::check(100, |g| {
            let len = g.usize_in(0, 64);
            let xs = stream_with_zeros(g, len);
            // Include the special coefficients 0 and 1 alongside random ones.
            let c = match g.u64() % 4 {
                0 => Gf16::ZERO,
                1 => Gf16::ONE,
                _ => Gf16(g.u64() as u16),
            };
            let mut bulk = xs.clone();
            mul_slice(c, &mut bulk);
            for (i, (&got, &x)) in bulk.iter().zip(&xs).enumerate() {
                let want = x.mul(c);
                if got != want {
                    return Err(format!(
                        "mul_slice mismatch at {i}: c={:#x} x={:#x} got={:#x} want={:#x}",
                        c.0, x.0, got.0, want.0
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_addmul_slice_matches_scalar_mul_add() {
        prop::check(100, |g| {
            let len = g.usize_in(0, 64);
            let xs = stream_with_zeros(g, len);
            let acc0 = stream_with_zeros(g, len);
            let c = match g.u64() % 4 {
                0 => Gf16::ZERO,
                1 => Gf16::ONE,
                _ => Gf16(g.u64() as u16),
            };
            let mut bulk = acc0.clone();
            addmul_slice(&mut bulk, c, &xs);
            for i in 0..len {
                let want = acc0[i].add(xs[i].mul(c));
                if bulk[i] != want {
                    return Err(format!(
                        "addmul_slice mismatch at {i}: c={:#x} acc={:#x} x={:#x} \
                         got={:#x} want={:#x}",
                        c.0, acc0[i].0, xs[i].0, bulk[i].0, want.0
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dot_matches_scalar_sum_of_products() {
        prop::check(100, |g| {
            let len = g.usize_in(0, 48);
            let a = stream_with_zeros(g, len);
            let b = stream_with_zeros(g, len);
            let want = a
                .iter()
                .zip(&b)
                .fold(Gf16::ZERO, |acc, (&x, &y)| acc.add(x.mul(y)));
            let got = dot(&a, &b);
            if got != want {
                return Err(format!("dot mismatch: got {:#x} want {:#x}", got.0, want.0));
            }
            Ok(())
        });
    }

    #[test]
    fn discrete_log_round_trips_through_pow() {
        let a = Gf16::alpha();
        for e in [0u64, 1, 2, 7, 1000, 65534] {
            assert_eq!(discrete_log(a.pow(e)) as u64, e % 65535, "e={e}");
        }
    }

    #[test]
    #[should_panic(expected = "discrete log of zero")]
    fn discrete_log_rejects_zero() {
        let _ = discrete_log(Gf16::ZERO);
    }

    #[test]
    fn prop_poly_eval_tile_matches_per_point_dot() {
        prop::check(60, |g| {
            let k = g.usize_in(1, 24);
            let tile = g.usize_in(1, 9);
            // Nonzero evaluation points with their log-domain power rows,
            // interleaved as [l][t].
            let points: Vec<Gf16> =
                (0..tile).map(|_| Gf16((g.u64() as u16).max(1))).collect();
            let mut lpow = vec![0u16; k * tile];
            for (t, &x) in points.iter().enumerate() {
                let lx = discrete_log(x) as u32;
                let mut cur = 0u32;
                for l in 0..k {
                    lpow[l * tile + t] = cur as u16;
                    cur += lx;
                    if cur >= 65535 {
                        cur -= 65535;
                    }
                }
            }
            let coeffs = stream_with_zeros(g, k);
            let mut got = vec![Gf16::ZERO; tile];
            poly_eval_tile(&coeffs, &lpow, tile, &mut got);
            for (t, &x) in points.iter().enumerate() {
                // Reference: explicit power row + dot.
                let mut powers = Vec::with_capacity(k);
                let mut p = Gf16::ONE;
                for _ in 0..k {
                    powers.push(p);
                    p = p.mul(x);
                }
                let want = dot(&coeffs, &powers);
                if got[t] != want {
                    return Err(format!(
                        "tile eval mismatch at t={t}: got {:#x} want {:#x} (k={k})",
                        got[t].0, want.0
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dot_power_row_matches_explicit_powers() {
        prop::check(80, |g| {
            let k = g.usize_in(0, 40);
            let coeffs = stream_with_zeros(g, k);
            // Random point, including zero (degenerate) and one.
            let x = match g.u64() % 5 {
                0 => Gf16::ZERO,
                1 => Gf16::ONE,
                _ => Gf16(g.u64() as u16),
            };
            let mut powers = Vec::with_capacity(k);
            let mut p = Gf16::ONE;
            for _ in 0..k {
                powers.push(p);
                p = p.mul(x);
            }
            let want = coeffs
                .iter()
                .zip(&powers)
                .fold(Gf16::ZERO, |acc, (&c, &pw)| acc.add(c.mul(pw)));
            let got = dot_power_row(&coeffs, x);
            if got != want {
                return Err(format!(
                    "dot_power_row mismatch: x={:#x} k={k} got={:#x} want={:#x}",
                    x.0, got.0, want.0
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn bulk_ops_edge_cases() {
        // Empty slices are fine.
        mul_slice(Gf16(7), &mut []);
        addmul_slice(&mut [], Gf16(7), &[]);
        assert_eq!(dot(&[], &[]), Gf16::ZERO);
        // c = 0 zero-fills / no-ops.
        let mut xs = vec![Gf16(3), Gf16(0), Gf16(0xFFFF)];
        mul_slice(Gf16::ZERO, &mut xs);
        assert!(xs.iter().all(|x| *x == Gf16::ZERO));
        let mut acc = vec![Gf16(5), Gf16(9)];
        addmul_slice(&mut acc, Gf16::ZERO, &[Gf16(1), Gf16(2)]);
        assert_eq!(acc, vec![Gf16(5), Gf16(9)]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn addmul_rejects_mismatched_lengths() {
        addmul_slice(&mut [Gf16(1)], Gf16(2), &[Gf16(1), Gf16(2)]);
    }
}
