//! GF(2^16) arithmetic with log/antilog tables.
//!
//! Substrate for the exact Reed–Solomon path (`rs.rs`): BICEC's (800, 3200)
//! code cannot be decoded in floating point, so payloads are quantised to
//! u16 fixed point and coded in an exact field.
//!
//! Field: GF(2^16) = GF(2)[x] / (x^16 + x^12 + x^3 + x + 1)  (0x1100B,
//! a standard primitive polynomial).

const POLY: u32 = 0x1100B;
const ORDER: usize = 1 << 16;

/// Precomputed log/exp tables (built once, lazily).
struct Tables {
    exp: Vec<u16>, // exp[i] = g^i, length 2*(ORDER-1) to skip a mod
    log: Vec<u16>, // log[x] for x != 0
}

fn tables() -> &'static Tables {
    use std::sync::OnceLock;
    static TABLES: OnceLock<Tables> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut exp = vec![0u16; 2 * (ORDER - 1)];
        let mut log = vec![0u16; ORDER];
        let mut x: u32 = 1;
        for i in 0..ORDER - 1 {
            exp[i] = x as u16;
            log[x as usize] = i as u16;
            x <<= 1;
            if x & (1 << 16) != 0 {
                x ^= POLY;
            }
        }
        for i in 0..ORDER - 1 {
            exp[ORDER - 1 + i] = exp[i];
        }
        Tables { exp, log }
    })
}

/// An element of GF(2^16).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default, Hash)]
pub struct Gf16(pub u16);

impl Gf16 {
    pub const ZERO: Gf16 = Gf16(0);
    pub const ONE: Gf16 = Gf16(1);

    #[inline]
    pub fn add(self, rhs: Gf16) -> Gf16 {
        Gf16(self.0 ^ rhs.0)
    }

    // Subtraction == addition in characteristic 2.
    #[inline]
    pub fn sub(self, rhs: Gf16) -> Gf16 {
        self.add(rhs)
    }

    #[inline]
    pub fn mul(self, rhs: Gf16) -> Gf16 {
        if self.0 == 0 || rhs.0 == 0 {
            return Gf16::ZERO;
        }
        let t = tables();
        let idx = t.log[self.0 as usize] as usize + t.log[rhs.0 as usize] as usize;
        Gf16(t.exp[idx])
    }

    #[inline]
    pub fn inv(self) -> Gf16 {
        assert!(self.0 != 0, "inverse of zero in GF(2^16)");
        let t = tables();
        let l = t.log[self.0 as usize] as usize;
        Gf16(t.exp[(ORDER - 1 - l) % (ORDER - 1)])
    }

    #[inline]
    pub fn div(self, rhs: Gf16) -> Gf16 {
        self.mul(rhs.inv())
    }

    pub fn pow(self, mut e: u64) -> Gf16 {
        if self.0 == 0 {
            return if e == 0 { Gf16::ONE } else { Gf16::ZERO };
        }
        let mut base = self;
        let mut acc = Gf16::ONE;
        while e > 0 {
            if e & 1 == 1 {
                acc = acc.mul(base);
            }
            base = base.mul(base);
            e >>= 1;
        }
        acc
    }

    /// The generator alpha (x).
    pub fn alpha() -> Gf16 {
        Gf16(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn additive_identity_and_self_inverse() {
        let a = Gf16(0x1234);
        assert_eq!(a.add(Gf16::ZERO), a);
        assert_eq!(a.add(a), Gf16::ZERO);
    }

    #[test]
    fn multiplicative_identity_and_inverse() {
        for v in [1u16, 2, 3, 0xFFFF, 0x8001, 257] {
            let a = Gf16(v);
            assert_eq!(a.mul(Gf16::ONE), a);
            assert_eq!(a.mul(a.inv()), Gf16::ONE, "v={v:#x}");
        }
    }

    #[test]
    fn alpha_has_full_order() {
        // alpha^(2^16 - 1) = 1 but alpha^m != 1 for the proper divisors'
        // quotient checks (65535 = 3 * 5 * 17 * 257).
        let a = Gf16::alpha();
        assert_eq!(a.pow(65535), Gf16::ONE);
        for d in [3u64, 5, 17, 257] {
            assert_ne!(a.pow(65535 / d), Gf16::ONE, "order divides 65535/{d}");
        }
    }

    #[test]
    fn prop_field_axioms() {
        prop::check(200, |g| {
            let a = Gf16(g.u64() as u16);
            let b = Gf16(g.u64() as u16);
            let c = Gf16(g.u64() as u16);
            // commutativity
            if a.mul(b) != b.mul(a) {
                return Err("mul not commutative".into());
            }
            // associativity
            if a.mul(b).mul(c) != a.mul(b.mul(c)) {
                return Err("mul not associative".into());
            }
            // distributivity
            if a.mul(b.add(c)) != a.mul(b).add(a.mul(c)) {
                return Err("not distributive".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_division_round_trip() {
        prop::check(200, |g| {
            let a = Gf16(g.u64() as u16);
            let b = Gf16((g.u64() as u16).max(1));
            if a.div(b).mul(b) != a {
                return Err(format!("(a/b)*b != a for a={:#x} b={:#x}", a.0, b.0));
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "inverse of zero")]
    fn zero_has_no_inverse() {
        let _ = Gf16::ZERO.inv();
    }
}
