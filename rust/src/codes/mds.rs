//! Real-valued (n, k) MDS code over matrix blocks.
//!
//! Encode: `Ĝ_i = Σ_j gen[i][j] · G_j`. Decode: invert the k x k submatrix
//! of any k completed rows and combine — exactly the L2 `coded_combine`
//! contraction; this rust path serves the native (non-PJRT) workers and the
//! master's decode.
//!
//! Generator families (measured worst-case subset condition, k=10, n=40,
//! 500 random subsets — see DESIGN.md §Numerical-fidelity):
//!
//! * `gaussian` (default): seeded N(0,1) entries — worst ≈ 5e3, median ≈ 29.
//!   Every k-subset is invertible with probability 1; f32 payload decodes
//!   to ~1e-4 relative error.
//! * `chebyshev`: Vandermonde at Chebyshev points — worst ≈ 9e9. Kept for
//!   the polynomial-code ablation; clustered subsets are rejected by the
//!   condition check rather than decoded to garbage.
//! * `integer_points`: the paper's literal `Â_n = A_1 + n·A_2` construction —
//!   subset condition up to 1e21; decodes are *always* rejected at K = 10.

use std::sync::{Arc, Mutex};

use crate::linalg::{combine, LuFactors, Matrix};
use crate::rng::{default_rng, Rng};

use super::cache::LruCache;
use super::Vandermonde;

#[derive(Debug)]
pub enum DecodeError {
    NotEnough { have: usize, need: usize },
    ShapeMismatch,
    DuplicateRow(usize),
    Singular(crate::linalg::LuError),
    IllConditioned { cond: f64, limit: f64 },
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::NotEnough { have, need } => {
                write!(f, "need {need} completed blocks, have {have}")
            }
            DecodeError::ShapeMismatch => write!(f, "block shape mismatch"),
            DecodeError::DuplicateRow(r) => write!(f, "duplicate code row {r}"),
            DecodeError::Singular(e) => write!(f, "decode submatrix singular: {e}"),
            DecodeError::IllConditioned { cond, limit } => {
                write!(f, "decode submatrix ill-conditioned: {cond:.3e} > {limit:.3e}")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

/// Inverted decode matrices kept per code (each is k² f64 plus its
/// measured condition estimate).
const DEFAULT_INVERSE_CACHE: usize = 8;

/// Real MDS code: any `k` of the `n` encoded blocks recover the `k` data
/// blocks (subject to the conditioning guard).
#[derive(Debug)]
pub struct RealMdsCode {
    n: usize,
    k: usize,
    /// Row-major (n x k) generator.
    gen: Vec<f64>,
    /// Reject decodes whose inf-norm condition estimate exceeds this.
    cond_limit: f64,
    /// Memoised `(inverse, cond)` per survivor subset. The cond is stored
    /// alongside so a cached entry is re-validated against the *current*
    /// `cond_limit` on every hit.
    inverse_cache: Mutex<LruCache<(Vec<f64>, f64)>>,
}

impl Clone for RealMdsCode {
    fn clone(&self) -> Self {
        let capacity = self.inverse_cache.lock().expect("mds cache lock").capacity();
        Self {
            n: self.n,
            k: self.k,
            gen: self.gen.clone(),
            cond_limit: self.cond_limit,
            inverse_cache: Mutex::new(LruCache::new(capacity)),
        }
    }
}

impl RealMdsCode {
    fn from_gen(n: usize, k: usize, gen: Vec<f64>) -> Self {
        Self {
            n,
            k,
            gen,
            cond_limit: 1e7,
            inverse_cache: Mutex::new(LruCache::new(DEFAULT_INVERSE_CACHE)),
        }
    }

    /// Default: seeded Gaussian generator (seed fixed for artifact
    /// reproducibility across master and workers).
    pub fn new(n: usize, k: usize) -> Self {
        Self::gaussian(n, k, 0x4D44_5343)
    }

    pub fn gaussian(n: usize, k: usize, seed: u64) -> Self {
        assert!(k >= 1 && n >= k, "need n >= k >= 1, got n={n} k={k}");
        let mut rng = default_rng(seed);
        // Irwin–Hall(12) ≈ N(0,1); keeps rng self-contained.
        let gen = (0..n * k)
            .map(|_| (0..12).map(|_| rng.next_f64()).sum::<f64>() - 6.0)
            .collect();
        Self::from_gen(n, k, gen)
    }

    /// Chebyshev-point Vandermonde (polynomial-code ablation).
    pub fn chebyshev(n: usize, k: usize) -> Self {
        let v = Vandermonde::chebyshev(n, k);
        let mut gen = Vec::with_capacity(n * k);
        for i in 0..n {
            gen.extend_from_slice(v.row(i));
        }
        Self::from_gen(n, k, gen)
    }

    /// Systematic variant: the first `k` coded blocks are the data blocks
    /// verbatim (identity prefix), the remaining `n - k` are Gaussian
    /// parity rows. When the first-k workers finish first the master skips
    /// the solve entirely — `decode` detects the identity subset.
    pub fn systematic(n: usize, k: usize) -> Self {
        let mut code = Self::gaussian(n, k, 0x5953_5445);
        for i in 0..k {
            for j in 0..k {
                code.gen[i * k + j] = if i == j { 1.0 } else { 0.0 };
            }
        }
        code
    }

    /// Paper-literal integer evaluation points (conditioning ablation).
    pub fn with_integer_points(n: usize, k: usize) -> Self {
        let v = Vandermonde::integer_points(n, k);
        let mut gen = Vec::with_capacity(n * k);
        for i in 0..n {
            gen.extend_from_slice(v.row(i));
        }
        Self::from_gen(n, k, gen)
    }

    pub fn with_cond_limit(mut self, limit: f64) -> Self {
        self.cond_limit = limit;
        self
    }

    /// Override the decode-inverse LRU capacity (0 disables caching —
    /// every decode re-runs the LU factorisation, the reference path).
    pub fn with_inverse_cache_capacity(self, capacity: usize) -> Self {
        *self.inverse_cache.lock().expect("mds cache lock") = LruCache::new(capacity);
        self
    }

    /// (hits, misses) of the decode-inverse cache since construction.
    pub fn inverse_cache_stats(&self) -> (u64, u64) {
        self.inverse_cache.lock().expect("mds cache lock").stats()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Generator row for encoded block `i` (length k).
    pub fn row(&self, i: usize) -> &[f64] {
        &self.gen[i * self.k..(i + 1) * self.k]
    }

    /// Generator row as f32 (PJRT payload dtype).
    pub fn row_f32(&self, i: usize) -> Vec<f32> {
        self.row(i).iter().map(|&v| v as f32).collect()
    }

    /// Full generator as f32 rows, for the PJRT `encode_*` artifact.
    pub fn generator_f32(&self) -> Vec<f32> {
        self.gen.iter().map(|&v| v as f32).collect()
    }

    /// Encode all `n` coded blocks from the `k` data blocks.
    pub fn encode(&self, data: &[Matrix]) -> Vec<Matrix> {
        (0..self.n).map(|i| self.encode_one(data, i)).collect()
    }

    /// Encode a single coded block (what worker `i` stores). The per-block
    /// accumulation is `Matrix::axpy`, which rides the dispatched
    /// `linalg::axpy_slice` kernel — like decode's fused combine, so the
    /// whole real-MDS path vectorises under the one `HCEC_FORCE_SCALAR`
    /// knob while staying bit-identical.
    pub fn encode_one(&self, data: &[Matrix], i: usize) -> Matrix {
        assert_eq!(data.len(), self.k, "need k data blocks");
        let row = self.row(i);
        let mut out = Matrix::zeros(data[0].rows(), data[0].cols());
        for (c, block) in row.iter().zip(data.iter()) {
            out.axpy(*c as f32, block);
        }
        out
    }

    /// Inverse of the k x k decode submatrix for `subset`, with an inf-norm
    /// condition check (‖A‖_∞ · ‖A⁻¹‖_∞). Served from the per-code LRU when
    /// the same survivor subset was inverted before; the condition estimate
    /// travels with the cached inverse and is re-checked against the
    /// current limit on every hit, so caching never widens acceptance.
    fn checked_inverse(&self, subset: &[usize]) -> Result<Arc<(Vec<f64>, f64)>, DecodeError> {
        if subset.len() != self.k {
            return Err(DecodeError::NotEnough { have: subset.len(), need: self.k });
        }
        {
            let mut seen = std::collections::HashSet::new();
            for &i in subset {
                assert!(i < self.n, "row {i} out of range");
                if !seen.insert(i) {
                    return Err(DecodeError::DuplicateRow(i));
                }
            }
        }
        let cached = self.inverse_cache.lock().expect("mds cache lock").get(subset);
        let entry = match cached {
            Some(entry) => entry,
            None => {
                // Factor outside the lock: the O(k³) solve must not
                // serialise concurrent decodes of different subsets.
                let entry = Arc::new(self.invert_subset_fresh(subset)?);
                self.inverse_cache
                    .lock()
                    .expect("mds cache lock")
                    .insert(subset.to_vec(), entry.clone());
                entry
            }
        };
        let cond = entry.1;
        if cond > self.cond_limit {
            return Err(DecodeError::IllConditioned { cond, limit: self.cond_limit });
        }
        Ok(entry)
    }

    /// Uncached inversion + condition estimate (the reference solve path).
    fn invert_subset_fresh(&self, subset: &[usize]) -> Result<(Vec<f64>, f64), DecodeError> {
        let k = self.k;
        let mut sub = Vec::with_capacity(k * k);
        for &r in subset {
            sub.extend_from_slice(self.row(r));
        }
        let factors = LuFactors::factor(k, &sub).map_err(DecodeError::Singular)?;
        let inv = factors.inverse();
        let norm_inf = |m: &[f64]| -> f64 {
            (0..k)
                .map(|i| m[i * k..(i + 1) * k].iter().map(|v| v.abs()).sum::<f64>())
                .fold(0.0, f64::max)
        };
        let cond = norm_inf(&sub) * norm_inf(&inv);
        Ok((inv, cond))
    }

    /// Decode the `k` data blocks from completed coded blocks.
    ///
    /// `completed` pairs each finished block with its code-row index. Extra
    /// completions beyond `k` are ignored (first k used), matching the
    /// master's behaviour of decoding as soon as the threshold is met.
    pub fn decode(&self, completed: &[(usize, &Matrix)]) -> Result<Vec<Matrix>, DecodeError> {
        let k = self.k;
        if completed.len() < k {
            return Err(DecodeError::NotEnough { have: completed.len(), need: k });
        }
        let used = &completed[..k];
        let (r, c) = (used[0].1.rows(), used[0].1.cols());
        if used.iter().any(|(_, m)| m.rows() != r || m.cols() != c) {
            return Err(DecodeError::ShapeMismatch);
        }
        let subset: Vec<usize> = used.iter().map(|(i, _)| *i).collect();
        // Systematic fast path: if the completed rows are exactly the data
        // rows 0..k (any order), the blocks *are* the data — no solve.
        if self.is_identity_subset(&subset) {
            let mut out = vec![Matrix::zeros(r, c); k];
            for (i, y) in used {
                out[*i] = (*y).clone();
            }
            return Ok(out);
        }
        let entry = self.checked_inverse(&subset)?;
        let inv = &entry.0;

        // out[j] = Σ_l inv[j][l] · used[l] — the coded_combine contraction,
        // fused row-wise (linalg::combine) so each output block is built in
        // one pass instead of k whole-matrix axpy sweeps.
        let blocks: Vec<&Matrix> = used.iter().map(|(_, y)| *y).collect();
        let mut coeffs = vec![0.0f32; k];
        let out = (0..k)
            .map(|j| {
                for (l, c) in coeffs.iter_mut().enumerate() {
                    *c = inv[j * k + l] as f32;
                }
                combine(&coeffs, &blocks)
            })
            .collect();
        Ok(out)
    }

    /// True when `subset` is a permutation of `0..k` *and* the generator's
    /// first k rows are the identity (systematic codes only).
    fn is_identity_subset(&self, subset: &[usize]) -> bool {
        if subset.len() != self.k || subset.iter().any(|&i| i >= self.k) {
            return false;
        }
        for i in 0..self.k {
            for j in 0..self.k {
                let want = if i == j { 1.0 } else { 0.0 };
                if self.gen[i * self.k + j] != want {
                    return false;
                }
            }
        }
        true
    }

    /// Inverse of the decode submatrix as f32 rows — handed to the PJRT
    /// `decode_*` artifact by the coordinator.
    pub fn decode_coeffs_f32(&self, subset: &[usize]) -> Result<Vec<f32>, DecodeError> {
        let mut out = Vec::new();
        self.decode_coeffs_f32_into(subset, &mut out)?;
        Ok(out)
    }

    /// Buffer-reusing form of [`decode_coeffs_f32`](Self::decode_coeffs_f32):
    /// the cluster decode loop runs this once per completion set with a
    /// pooled scratch buffer, so the per-set coefficient allocation
    /// disappears from the steady state.
    pub fn decode_coeffs_f32_into(
        &self,
        subset: &[usize],
        out: &mut Vec<f32>,
    ) -> Result<(), DecodeError> {
        let inv = self.checked_inverse(subset)?;
        out.clear();
        out.extend(inv.0.iter().map(|&v| v as f32));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::rng::default_rng;

    fn random_blocks(k: usize, r: usize, c: usize, seed: u64) -> Vec<Matrix> {
        let mut rng = default_rng(seed);
        (0..k).map(|_| Matrix::random(r, c, &mut rng)).collect()
    }

    #[test]
    fn encode_decode_identity_subset() {
        let code = RealMdsCode::new(8, 4);
        let data = random_blocks(4, 3, 5, 1);
        let coded = code.encode(&data);
        let completed: Vec<(usize, &Matrix)> =
            (0..4).map(|i| (i, &coded[i])).collect();
        let decoded = code.decode(&completed).unwrap();
        for (d, want) in decoded.iter().zip(&data) {
            assert!(d.max_abs_diff(want) < 1e-3, "diff={}", d.max_abs_diff(want));
        }
    }

    #[test]
    fn decode_from_last_k_rows() {
        let code = RealMdsCode::new(10, 3);
        let data = random_blocks(3, 2, 2, 2);
        let coded = code.encode(&data);
        let completed: Vec<(usize, &Matrix)> =
            (7..10).map(|i| (i, &coded[i])).collect();
        let decoded = code.decode(&completed).unwrap();
        for (d, want) in decoded.iter().zip(&data) {
            assert!(d.max_abs_diff(want) < 1e-3);
        }
    }

    #[test]
    fn decode_needs_k_blocks() {
        let code = RealMdsCode::new(6, 3);
        let data = random_blocks(3, 2, 2, 3);
        let coded = code.encode(&data);
        let completed: Vec<(usize, &Matrix)> = vec![(0, &coded[0]), (1, &coded[1])];
        assert!(matches!(
            code.decode(&completed),
            Err(DecodeError::NotEnough { have: 2, need: 3 })
        ));
    }

    #[test]
    fn decode_rejects_duplicate_rows() {
        let code = RealMdsCode::new(6, 3);
        let data = random_blocks(3, 2, 2, 3);
        let coded = code.encode(&data);
        let completed: Vec<(usize, &Matrix)> =
            vec![(0, &coded[0]), (0, &coded[0]), (1, &coded[1])];
        assert!(matches!(
            code.decode(&completed),
            Err(DecodeError::DuplicateRow(0))
        ));
    }

    #[test]
    fn encode_is_linear_in_data() {
        let code = RealMdsCode::new(5, 2);
        let d1 = random_blocks(2, 2, 3, 4);
        let d2 = random_blocks(2, 2, 3, 5);
        let mut sum = vec![d1[0].clone(), d1[1].clone()];
        sum[0].axpy(1.0, &d2[0]);
        sum[1].axpy(1.0, &d2[1]);
        let lhs = code.encode_one(&sum, 3);
        let mut rhs = code.encode_one(&d1, 3);
        rhs.axpy(1.0, &code.encode_one(&d2, 3));
        assert!(lhs.max_abs_diff(&rhs) < 1e-4);
    }

    #[test]
    fn prop_any_subset_recovers_k10() {
        // The figure configuration: (40, 10) code — any 10-of-40 must decode.
        let code = RealMdsCode::new(40, 10);
        let data = random_blocks(10, 2, 4, 6);
        let coded = code.encode(&data);
        prop::check(40, |g| {
            let mut rows: Vec<usize> = (0..40).collect();
            g.shuffle(&mut rows);
            let subset: Vec<usize> = rows.into_iter().take(10).collect();
            let completed: Vec<(usize, &Matrix)> =
                subset.iter().map(|&i| (i, &coded[i])).collect();
            let decoded = code.decode(&completed).map_err(|e| e.to_string())?;
            let scale = data.iter().map(|m| m.max_abs()).fold(1.0, f32::max);
            for (d, want) in decoded.iter().zip(&data) {
                let err = d.max_abs_diff(want) / scale;
                if err > 1e-2 {
                    return Err(format!("recovery err {err} for subset {subset:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn integer_points_decode_rejected_as_ill_conditioned() {
        // The paper-literal construction must *fail loudly*, not decode
        // garbage: K=10 with integer points 31..40 has cond ~1e21.
        let code = RealMdsCode::with_integer_points(40, 10);
        let data = random_blocks(10, 2, 2, 7);
        let coded = code.encode(&data);
        let completed: Vec<(usize, &Matrix)> =
            (30..40).map(|i| (i, &coded[i])).collect();
        match code.decode(&completed) {
            Err(DecodeError::IllConditioned { .. }) => {}
            other => panic!("expected IllConditioned, got {other:?}"),
        }
    }

    #[test]
    fn gaussian_beats_chebyshev_on_clustered_subsets() {
        // Adjacent-row subsets are the adversarial case for Vandermonde;
        // the Gaussian default must decode where Chebyshev is rejected.
        let subset: Vec<usize> = (28..38).collect();
        let gauss = RealMdsCode::new(40, 10);
        let cheb = RealMdsCode::chebyshev(40, 10);
        assert!(gauss.decode_coeffs_f32(&subset).is_ok());
        assert!(matches!(
            cheb.decode_coeffs_f32(&subset),
            Err(DecodeError::IllConditioned { .. })
        ));
    }

    #[test]
    fn systematic_identity_prefix_roundtrip() {
        let code = RealMdsCode::systematic(8, 3);
        let data = random_blocks(3, 2, 4, 11);
        let coded = code.encode(&data);
        // First k coded blocks are the data verbatim.
        for i in 0..3 {
            assert_eq!(coded[i].max_abs_diff(&data[i]), 0.0, "block {i}");
        }
        // Identity-subset decode is exact (no solve), in any arrival order.
        let completed: Vec<(usize, &Matrix)> =
            vec![(2, &coded[2]), (0, &coded[0]), (1, &coded[1])];
        let decoded = code.decode(&completed).unwrap();
        for (d, want) in decoded.iter().zip(&data) {
            assert_eq!(d.max_abs_diff(want), 0.0);
        }
    }

    #[test]
    fn systematic_parity_subsets_still_decode() {
        let code = RealMdsCode::systematic(8, 3);
        let data = random_blocks(3, 2, 4, 12);
        let coded = code.encode(&data);
        let completed: Vec<(usize, &Matrix)> =
            vec![(7, &coded[7]), (0, &coded[0]), (5, &coded[5])];
        let decoded = code.decode(&completed).unwrap();
        for (d, want) in decoded.iter().zip(&data) {
            assert!(d.max_abs_diff(want) < 1e-3);
        }
    }

    #[test]
    fn prop_cached_inverse_decode_equals_fresh_solve() {
        // The inverse LRU must be semantically invisible across random
        // survivor subsets; a cache-disabled clone is the reference.
        let cached = RealMdsCode::new(24, 6);
        let fresh = cached.clone().with_inverse_cache_capacity(0);
        let data = random_blocks(6, 3, 5, 21);
        let coded = cached.encode(&data);
        prop::check(30, |g| {
            let mut rows: Vec<usize> = (0..24).collect();
            g.shuffle(&mut rows);
            let subset: Vec<usize> = rows.into_iter().take(6).collect();
            let completed: Vec<(usize, &Matrix)> =
                subset.iter().map(|&i| (i, &coded[i])).collect();
            // Twice on the caching code: the second decode is an LRU hit.
            let warm = cached.decode(&completed).map_err(|e| e.to_string())?;
            let hit = cached.decode(&completed).map_err(|e| e.to_string())?;
            let reference = fresh.decode(&completed).map_err(|e| e.to_string())?;
            for j in 0..6 {
                if warm[j].max_abs_diff(&reference[j]) != 0.0
                    || hit[j].max_abs_diff(&reference[j]) != 0.0
                {
                    return Err(format!("cached decode diverged at block {j}"));
                }
            }
            Ok(())
        });
        let (hits, _) = cached.inverse_cache_stats();
        assert!(hits > 0, "repeat decodes must hit the cache");
        let (fresh_hits, _) = fresh.inverse_cache_stats();
        assert_eq!(fresh_hits, 0, "capacity-0 cache can never hit");
    }

    #[test]
    fn cache_eviction_never_changes_results() {
        let code = RealMdsCode::new(12, 3).with_inverse_cache_capacity(2);
        let reference = code.clone().with_inverse_cache_capacity(0);
        let data = random_blocks(3, 2, 4, 22);
        let coded = code.encode(&data);
        // 5 subsets cycled twice through a capacity-2 cache: constant
        // eviction, results must stay equal to the uncached path.
        let subsets: [[usize; 3]; 5] =
            [[11, 4, 7], [3, 9, 5], [10, 6, 8], [4, 11, 9], [5, 7, 3]];
        for round in 0..2 {
            for subset in &subsets {
                let completed: Vec<(usize, &Matrix)> =
                    subset.iter().map(|&i| (i, &coded[i])).collect();
                let got = code.decode(&completed).unwrap();
                let want = reference.decode(&completed).unwrap();
                for j in 0..3 {
                    assert_eq!(
                        got[j].max_abs_diff(&want[j]),
                        0.0,
                        "round {round} subset {subset:?} block {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn cached_rejections_stay_rejections() {
        // An ill-conditioned subset must be rejected on the cache hit too.
        let code = RealMdsCode::with_integer_points(40, 10);
        let subset: Vec<usize> = (30..40).collect();
        for _ in 0..2 {
            assert!(matches!(
                code.decode_coeffs_f32(&subset),
                Err(DecodeError::IllConditioned { .. })
            ));
        }
    }

    #[test]
    fn decode_coeffs_match_decode() {
        // Combining with decode_coeffs_f32 by hand equals decode().
        let code = RealMdsCode::new(7, 3);
        let data = random_blocks(3, 2, 2, 9);
        let coded = code.encode(&data);
        let subset = [6usize, 2, 4];
        let inv = code.decode_coeffs_f32(&subset).unwrap();
        let completed: Vec<(usize, &Matrix)> =
            subset.iter().map(|&i| (i, &coded[i])).collect();
        let decoded = code.decode(&completed).unwrap();
        for j in 0..3 {
            let mut manual = Matrix::zeros(2, 2);
            for (l, &i) in subset.iter().enumerate() {
                manual.axpy(inv[j * 3 + l], &coded[i]);
            }
            assert!(manual.max_abs_diff(&decoded[j]) < 1e-5);
        }
    }
}
