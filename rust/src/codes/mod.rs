//! MDS coding substrate.
//!
//! Two families (DESIGN.md §Substitutions):
//!
//! * `RealMdsCode` — Vandermonde over f64 with Chebyshev evaluation points.
//!   Paper-faithful (polynomial codes, [3]); numerically sound for the
//!   K ≈ 10–32 range used by CEC/MLCEC and the end-to-end driver.
//! * `RsCode` over GF(2^16) — exact recovery at any K (BICEC's K = 800),
//!   operating on fixed-point-quantised payloads. The paper never verified
//!   numerics at K = 800; we can, because the field is exact.
//!
//! `cost` is the decode-cost model used by the figure benches (the paper's
//! own accounting: Vandermonde inverse + K·u·v combine MACs).

mod cache;
pub mod cost;
mod gf;
mod mds;
mod rs;
pub mod simd;
mod vandermonde;

pub use gf::{
    addmul_slice, addmul_slice_scalar, discrete_log, dot, dot_power_row, dot_scalar,
    mul_slice, mul_slice_scalar, poly_eval_tile, poly_eval_tile_scalar, Gf16,
};
pub use mds::{DecodeError, RealMdsCode};
pub use rs::{dequantize, quantize, RsCode, ENCODE_TILE};
pub use vandermonde::{chebyshev_points, vandermonde, Vandermonde};
