//! Decode-cost model — the paper's own accounting (Sec. 3):
//!
//! * CEC / MLCEC: invert one K x K Vandermonde (after the inverse, the
//!   combine does K·u·v multiply-adds in total across the N sets).
//! * BICEC: invert one K_bicec x K_bicec Vandermonde, then K_bicec·u·v
//!   multiply-adds.
//!
//! The model returns abstract *operation counts*; `sim::CostModel` converts
//! them to time with the calibrated decode rate. Fig 2b is this module
//! swept over N and the two matrix shapes.

/// Operations to invert a k x k system via LU (2/3 k^3 flops, plus k^2 per
/// RHS for the k RHS columns of the inverse -> k^3 total order).
pub fn inverse_ops(k: usize) -> u64 {
    let k = k as u64;
    (2 * k * k * k) / 3 + k * k * k
}

/// Combine (coded_combine) multiply-adds to reconstruct the full u x v
/// output from k completed coded blocks: k · u · v.
pub fn combine_ops(k: usize, u: usize, v: usize) -> u64 {
    k as u64 * u as u64 * v as u64
}

/// Total decode ops for a scheme with code dimension k on a u x v output.
pub fn decode_ops(k: usize, u: usize, v: usize) -> u64 {
    inverse_ops(k) + combine_ops(k, u, v)
}

/// Worker-side computation ops for the whole job: u·w·v multiply-adds.
pub fn job_ops(u: usize, w: usize, v: usize) -> u64 {
    u as u64 * w as u64 * v as u64
}

/// Ops per CEC/MLCEC subtask: the encoded task is u/K rows; each of the N
/// subtasks is u/(K·N) rows against the full B.
pub fn cec_subtask_ops(u: usize, w: usize, v: usize, k: usize, n: usize) -> u64 {
    job_ops(u, w, v) / (k as u64 * n as u64)
}

/// Ops per BICEC subtask: the job is split into K_bicec computations, each
/// encoded subtask has the same size.
pub fn bicec_subtask_ops(u: usize, w: usize, v: usize, k_bicec: usize) -> u64 {
    job_ops(u, w, v) / k_bicec as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_decode_totals() {
        // Paper Sec. 3: CEC/MLCEC combine = 10·u·v; BICEC combine = 800·u·v.
        let (u, v) = (2400, 2400);
        assert_eq!(combine_ops(10, u, v), 10 * 2400 * 2400);
        assert_eq!(combine_ops(800, u, v), 800 * 2400 * 2400);
    }

    #[test]
    fn bicec_decode_dominates_cec_decode() {
        let (u, v) = (2400, 2400);
        assert!(decode_ops(800, u, v) > 50 * decode_ops(10, u, v));
    }

    #[test]
    fn decode_grows_with_v() {
        // Fig 2b: (2400, 960, 6000) decodes slower than (2400, 2400, 2400).
        assert!(decode_ops(800, 2400, 6000) > decode_ops(800, 2400, 2400));
    }

    #[test]
    fn per_worker_budgets_match_paper() {
        // Sec. 3: every scheme tasks a worker with at most uwv/10 ops.
        let (u, w, v) = (2400, 2400, 2400);
        let total = job_ops(u, w, v);
        // CEC/MLCEC at N=40: S=20 subtasks of uwv/(10·40) each.
        assert_eq!(20 * cec_subtask_ops(u, w, v, 10, 40), total / 20);
        // BICEC: S=80 subtasks of uwv/800 each.
        assert_eq!(80 * bicec_subtask_ops(u, w, v, 800), total / 10);
    }

    #[test]
    fn subtask_ops_divide_evenly_for_figure_grid() {
        for n in (20..=40).step_by(2) {
            let ops = cec_subtask_ops(2400, 2400, 2400, 10, n);
            assert!(ops > 0);
        }
    }
}
