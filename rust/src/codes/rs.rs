//! Reed–Solomon (n, k) code over GF(2^16) — exact recovery at BICEC scale.
//!
//! Encode: evaluate the degree-(k-1) polynomial with the data symbols as
//! coefficients at n distinct field points (alpha^0 ... alpha^(n-1)).
//! Decode (no errors, only erasures — finished/unfinished workers): solve
//! the k x k Vandermonde system over the field via Gaussian elimination.
//! n is bounded by the field order; BICEC's n = 3200 is comfortable.
//!
//! Hot-path structure (the batch-throughput pass):
//!
//! * `encode_shares` tiles the encode: a tile of [`ENCODE_TILE`] shares is
//!   evaluated per pass over the data through log-domain power rows
//!   (`gf::poly_eval_tile`), so each stream position's coefficient logs
//!   are looked up once and shared by every share in the tile —
//!   `encode_share` is the tile-of-one special case (`gf::dot_power_row`).
//!   The bulk kernels (`poly_eval_tile`, `mul_slice`, `addmul_slice`) ride
//!   the runtime SIMD dispatch in `codes::simd`, so encode, the O(k³)
//!   Gauss–Jordan solve, and the decode combine all vectorise on AVX2
//!   while staying bit-identical to the scalar oracles.
//! * `decode` splits into (a) obtaining the inverted k x k decode matrix
//!   and (b) the combine, `out[j] = Σ_l inv[j][l] · share_l`, written with
//!   `gf::addmul_slice` so long symbol streams amortise every lookup.
//! * Inverted decode matrices are memoised in a small LRU keyed by the
//!   survivor-index subset: the master decodes many streams (and many
//!   Monte-Carlo trials) against the *same* completed set, and the O(k³)
//!   Gauss–Jordan at k = 800 would otherwise dominate every decode.
//!
//! Payloads are `u16` symbols; `quantize`/`dequantize` map f32 matrices to
//! symbol streams losslessly enough for verification (12-bit mantissa grid).

use std::sync::{Arc, Mutex};

use super::cache::LruCache;
use super::gf::{addmul_slice, discrete_log, dot_power_row, poly_eval_tile, Gf16};

#[derive(Debug)]
pub enum RsError {
    NotEnough { have: usize, need: usize },
    DuplicateRow(usize),
    TooLong { n: usize },
}

impl std::fmt::Display for RsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RsError::NotEnough { have, need } => write!(f, "have {have} < k={need} shares"),
            RsError::DuplicateRow(r) => write!(f, "duplicate evaluation row {r}"),
            RsError::TooLong { n } => write!(f, "n={n} exceeds field order - 1"),
        }
    }
}

impl std::error::Error for RsError {}

/// Default number of inverted decode matrices kept per code. Each entry is
/// k² symbols (1.25 MiB at k = 800), so the cap stays small; the master
/// only ever cycles through a handful of live completed sets at a time.
const DEFAULT_DECODE_CACHE: usize = 8;

/// Shares encoded per pass over the data by `encode_shares`: the tile's
/// log-power rows (`ENCODE_TILE` u16s per coefficient) plus the
/// coefficient stream stay cache-resident at the BICEC scale (k = 800).
/// 32 gives the SIMD tile kernel four full 8-lane gather groups while the
/// k = 800 power rows stay at 50 KiB; the tiled results are exact, so the
/// widening from the original 8 changes no output.
pub const ENCODE_TILE: usize = 32;

/// Systematic-free RS code: share i = p(alpha^i), p's coefficients = data.
#[derive(Debug)]
pub struct RsCode {
    n: usize,
    k: usize,
    /// Evaluation points alpha^i.
    points: Vec<Gf16>,
    cache: Mutex<LruCache<Vec<Gf16>>>,
}

impl Clone for RsCode {
    fn clone(&self) -> Self {
        let capacity = self.cache.lock().expect("rs cache lock").capacity();
        Self {
            n: self.n,
            k: self.k,
            points: self.points.clone(),
            cache: Mutex::new(LruCache::new(capacity)),
        }
    }
}

impl RsCode {
    pub fn new(n: usize, k: usize) -> Result<Self, RsError> {
        if n >= (1 << 16) {
            return Err(RsError::TooLong { n });
        }
        assert!(k >= 1 && n >= k, "need n >= k >= 1");
        let a = Gf16::alpha();
        let points = (0..n).map(|i| a.pow(i as u64)).collect();
        Ok(Self { n, k, points, cache: Mutex::new(LruCache::new(DEFAULT_DECODE_CACHE)) })
    }

    /// Override the decode-matrix LRU capacity (0 disables caching — every
    /// decode re-runs the Gauss–Jordan, the reference behaviour).
    pub fn with_decode_cache_capacity(self, capacity: usize) -> Self {
        *self.cache.lock().expect("rs cache lock") = LruCache::new(capacity);
        self
    }

    /// (hits, misses) of the decode-matrix cache since construction.
    pub fn decode_cache_stats(&self) -> (u64, u64) {
        self.cache.lock().expect("rs cache lock").stats()
    }

    /// Number of inverted matrices currently cached.
    pub fn decode_cache_len(&self) -> usize {
        self.cache.lock().expect("rs cache lock").len()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Encode one share: data is a stream of symbol vectors, each of length
    /// k (one polynomial per stream position). Output has the same stream
    /// length, one symbol per position. The tile-of-one case of
    /// [`encode_shares`](Self::encode_shares): `gf::dot_power_row` walks
    /// the same log-domain arithmetic progression the tiled kernel uses,
    /// without materialising power rows, so single-share encode and the
    /// batch encoder share one inner loop.
    pub fn encode_share(&self, data: &[Vec<Gf16>], share: usize) -> Vec<Gf16> {
        assert!(share < self.n, "share {share} out of range (n = {})", self.n);
        let x = self.points[share];
        data.iter()
            .map(|coeffs| {
                debug_assert_eq!(coeffs.len(), self.k);
                dot_power_row(coeffs, x)
            })
            .collect()
    }

    /// Encode several shares with shared power-row tiling: each tile of
    /// [`ENCODE_TILE`] shares is evaluated in ONE pass over the data. The
    /// tile's evaluation-point powers are precomputed in the log domain
    /// (`lpow[l][t] = log(x_t^l)`, an arithmetic progression mod 2^16 - 1),
    /// so per stream position each coefficient's log is read once and
    /// combined with every share's power by a single exp-table lookup —
    /// where per-share encodes re-walk the data (and the log table) once
    /// per share. Entry `i` equals `encode_share(data, shares[i])` exactly.
    pub fn encode_shares(&self, data: &[Vec<Gf16>], shares: &[usize]) -> Vec<Vec<Gf16>> {
        let mut out: Vec<Vec<Gf16>> =
            shares.iter().map(|_| vec![Gf16::ZERO; data.len()]).collect();
        let mut lpow: Vec<u16> = Vec::new();
        let mut acc = [Gf16::ZERO; ENCODE_TILE];
        for (chunk_idx, tile_shares) in shares.chunks(ENCODE_TILE).enumerate() {
            let tile_start = chunk_idx * ENCODE_TILE;
            let tile = tile_shares.len();
            // lpow[l * tile + t] = log(points[share_t]^l), interleaved so
            // the kernel's inner loop over the tile is contiguous.
            lpow.clear();
            lpow.resize(self.k * tile, 0);
            for (t, &share) in tile_shares.iter().enumerate() {
                assert!(share < self.n, "share {share} out of range (n = {})", self.n);
                let lx = discrete_log(self.points[share]) as u32;
                let mut cur = 0u32;
                for l in 0..self.k {
                    lpow[l * tile + t] = cur as u16;
                    cur += lx;
                    if cur >= 65535 {
                        cur -= 65535;
                    }
                }
            }
            for (pos, coeffs) in data.iter().enumerate() {
                debug_assert_eq!(coeffs.len(), self.k);
                let acc = &mut acc[..tile];
                acc.fill(Gf16::ZERO);
                poly_eval_tile(coeffs, &lpow, tile, acc);
                for (t, &sym) in acc.iter().enumerate() {
                    out[tile_start + t][pos] = sym;
                }
            }
        }
        out
    }

    /// Invert the k x k Vandermonde of the given evaluation rows via
    /// Gauss–Jordan over the field (exact; any nonzero pivot works, and
    /// distinct points guarantee invertibility). Row-major k x k output.
    /// This is the uncached reference path.
    pub fn invert_rows_fresh(&self, rows: &[usize]) -> Vec<Gf16> {
        let k = self.k;
        assert_eq!(rows.len(), k, "need exactly k rows");
        let w = 2 * k;
        let mut aug: Vec<Gf16> = Vec::with_capacity(k * w);
        for &i in rows {
            let x = self.points[i];
            let mut p = Gf16::ONE;
            for _ in 0..k {
                aug.push(p);
                p = p.mul(x);
            }
            for _ in 0..k {
                aug.push(Gf16::ZERO);
            }
        }
        for r in 0..k {
            aug[r * w + k + r] = Gf16::ONE;
        }
        for col in 0..k {
            let pivot_row = (col..k)
                .find(|&r| aug[r * w + col] != Gf16::ZERO)
                .expect("Vandermonde over distinct points is invertible");
            if pivot_row != col {
                for j in 0..w {
                    aug.swap(col * w + j, pivot_row * w + j);
                }
            }
            let inv = aug[col * w + col].inv();
            {
                let row = &mut aug[col * w..col * w + w];
                super::gf::mul_slice(inv, row);
            }
            for r in 0..k {
                if r != col && aug[r * w + col] != Gf16::ZERO {
                    let f = aug[r * w + col];
                    // row_r += f * row_col (XOR add); split_at_mut gives the
                    // two disjoint rows.
                    let (lo, hi) = aug.split_at_mut(col.max(r) * w);
                    let (src, dst) = if r > col {
                        (&lo[col * w..col * w + w], &mut hi[..w])
                    } else {
                        (&hi[..w], &mut lo[r * w..r * w + w])
                    };
                    addmul_slice(dst, f, src);
                }
            }
        }
        // Extract the right half (the inverse).
        let mut out = Vec::with_capacity(k * k);
        for r in 0..k {
            out.extend_from_slice(&aug[r * w + k..r * w + w]);
        }
        out
    }

    /// The inverted decode matrix for `rows`, served from the LRU when the
    /// same survivor subset was inverted before.
    pub fn decode_matrix(&self, rows: &[usize]) -> Arc<Vec<Gf16>> {
        {
            let mut cache = self.cache.lock().expect("rs cache lock");
            if let Some(inv) = cache.get(rows) {
                return inv;
            }
        }
        // Invert outside the lock: the O(k³) solve must not serialise
        // concurrent decodes of different subsets.
        let inv = Arc::new(self.invert_rows_fresh(rows));
        self.cache
            .lock()
            .expect("rs cache lock")
            .insert(rows.to_vec(), inv.clone());
        inv
    }

    /// Decode the k data symbols per stream position from k completed
    /// shares `(share_index, symbols)`.
    pub fn decode(
        &self,
        completed: &[(usize, &[Gf16])],
    ) -> Result<Vec<Vec<Gf16>>, RsError> {
        if completed.len() < self.k {
            return Err(RsError::NotEnough { have: completed.len(), need: self.k });
        }
        let used = &completed[..self.k];
        {
            let mut seen = std::collections::HashSet::new();
            for (i, _) in used {
                if !seen.insert(*i) {
                    return Err(RsError::DuplicateRow(*i));
                }
            }
        }
        let k = self.k;
        let stream_len = used[0].1.len();
        assert!(used.iter().all(|(_, s)| s.len() == stream_len));

        let rows: Vec<usize> = used.iter().map(|(i, _)| *i).collect();
        let inv = self.decode_matrix(&rows);

        // Combine: out[j][pos] = Σ_l inv[j][l] · used[l][pos], one bulk
        // addmul per (j, l) so the stream loop never re-reads the tables.
        let mut out = vec![vec![Gf16::ZERO; stream_len]; k];
        for (j, row) in out.iter_mut().enumerate() {
            for (l, (_, sym)) in used.iter().enumerate() {
                addmul_slice(row, inv[j * k + l], sym);
            }
        }
        Ok(out)
    }
}

/// Quantise f32 values into u16 symbols on a uniform grid over
/// [-scale, scale]. Round-trips with absolute error <= scale / 65535.
pub fn quantize(values: &[f32], scale: f32) -> Vec<Gf16> {
    assert!(scale > 0.0);
    values
        .iter()
        .map(|&v| {
            let clamped = v.clamp(-scale, scale);
            let t = (clamped + scale) / (2.0 * scale); // [0, 1]
            Gf16((t * 65535.0).round() as u16)
        })
        .collect()
}

/// Inverse of `quantize`.
pub fn dequantize(symbols: &[Gf16], scale: f32) -> Vec<f32> {
    symbols
        .iter()
        .map(|s| (s.0 as f32 / 65535.0) * 2.0 * scale - scale)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    fn sym(v: u16) -> Gf16 {
        Gf16(v)
    }

    #[test]
    fn encode_decode_small() {
        let code = RsCode::new(6, 3).unwrap();
        let data = vec![
            vec![sym(1), sym(2), sym(3)],
            vec![sym(100), sym(200), sym(300)],
        ];
        let shares: Vec<Vec<Gf16>> =
            (0..6).map(|i| code.encode_share(&data, i)).collect();
        let completed: Vec<(usize, &[Gf16])> =
            vec![(5, &shares[5][..]), (1, &shares[1][..]), (3, &shares[3][..])];
        let decoded = code.decode(&completed).unwrap();
        // decoded[j][pos] must equal data[pos][j]
        for pos in 0..2 {
            for j in 0..3 {
                assert_eq!(decoded[j][pos], data[pos][j], "pos={pos} j={j}");
            }
        }
    }

    #[test]
    fn decode_rejects_duplicates_and_shortage() {
        let code = RsCode::new(4, 2).unwrap();
        let data = vec![vec![sym(7), sym(9)]];
        let s0 = code.encode_share(&data, 0);
        assert!(matches!(
            code.decode(&[(0, &s0[..])]),
            Err(RsError::NotEnough { .. })
        ));
        assert!(matches!(
            code.decode(&[(0, &s0[..]), (0, &s0[..])]),
            Err(RsError::DuplicateRow(0))
        ));
    }

    #[test]
    fn prop_any_k_subset_recovers() {
        prop::check(30, |g| {
            let k = g.usize_in(1, 12);
            let n = k + g.usize_in(0, 20);
            let code = RsCode::new(n, k).unwrap();
            let stream = g.usize_in(1, 8);
            let data: Vec<Vec<Gf16>> = (0..stream)
                .map(|_| (0..k).map(|_| Gf16(g.u64() as u16)).collect())
                .collect();
            let shares: Vec<Vec<Gf16>> =
                (0..n).map(|i| code.encode_share(&data, i)).collect();
            let mut order: Vec<usize> = (0..n).collect();
            g.shuffle(&mut order);
            let completed: Vec<(usize, &[Gf16])> =
                order.iter().take(k).map(|&i| (i, &shares[i][..])).collect();
            let decoded = code.decode(&completed).map_err(|e| e.to_string())?;
            for pos in 0..stream {
                for j in 0..k {
                    if decoded[j][pos] != data[pos][j] {
                        return Err(format!("mismatch at pos={pos} j={j} (n={n} k={k})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_encode_shares_matches_per_share_encode() {
        // The tiled encoder must be bit-identical to per-share evaluation,
        // across tile-boundary lengths, duplicates, and arbitrary order.
        prop::check(25, |g| {
            let k = g.usize_in(1, 12);
            let n = k + g.usize_in(0, 20);
            let code = RsCode::new(n, k).unwrap();
            let stream = g.usize_in(0, 6);
            let data: Vec<Vec<Gf16>> = (0..stream)
                .map(|_| (0..k).map(|_| Gf16(g.u64() as u16)).collect())
                .collect();
            // 0..=2*ENCODE_TILE+1 shares crosses whole-tile and remainder
            // paths; duplicates are legal.
            let count = g.usize_in(0, 2 * ENCODE_TILE + 1);
            let shares: Vec<usize> = (0..count).map(|_| g.usize_in(0, n - 1)).collect();
            let tiled = code.encode_shares(&data, &shares);
            if tiled.len() != shares.len() {
                return Err(format!("{} outputs for {} shares", tiled.len(), shares.len()));
            }
            for (i, &s) in shares.iter().enumerate() {
                // Reference: the original power-row + dot evaluation.
                let x = code.points[s];
                let mut powers = Vec::with_capacity(k);
                let mut p = Gf16::ONE;
                for _ in 0..k {
                    powers.push(p);
                    p = p.mul(x);
                }
                let want: Vec<Gf16> =
                    data.iter().map(|coeffs| super::super::gf::dot(coeffs, &powers)).collect();
                if tiled[i] != want {
                    return Err(format!(
                        "share {s} (slot {i}) diverged from reference (n={n} k={k})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bicec_scale_code_constructs_and_decodes() {
        // The paper's BICEC configuration: (3200, 800). Exactness at scale,
        // through the tiled multi-share encoder.
        let code = RsCode::new(3200, 800).unwrap();
        let data: Vec<Vec<Gf16>> = vec![(0..800).map(|i| Gf16(i as u16 * 7 + 1)).collect()];
        // Encode a scattered subset of shares and decode from them.
        let subset: Vec<usize> = (0..800).map(|i| i * 4 % 3200 + i / 800).collect();
        let shares: Vec<Vec<Gf16>> = code.encode_shares(&data, &subset);
        let completed: Vec<(usize, &[Gf16])> = subset
            .iter()
            .zip(shares.iter())
            .map(|(&i, s)| (i, &s[..]))
            .collect();
        let decoded = code.decode(&completed).unwrap();
        for j in 0..800 {
            assert_eq!(decoded[j][0], data[0][j]);
        }
    }

    #[test]
    fn quantize_round_trip_error_bound() {
        let vals = [-1.0f32, -0.5, 0.0, 0.25, 0.999, 1.0];
        let q = quantize(&vals, 1.0);
        let back = dequantize(&q, 1.0);
        for (v, b) in vals.iter().zip(&back) {
            assert!((v - b).abs() <= 1.0 / 65535.0 + 1e-7, "{v} vs {b}");
        }
    }

    // ---- decode-matrix cache -------------------------------------------

    #[test]
    fn repeated_decode_hits_cache() {
        let code = RsCode::new(8, 3).unwrap();
        let data = vec![vec![sym(11), sym(22), sym(33)]];
        let shares: Vec<Vec<Gf16>> =
            (0..8).map(|i| code.encode_share(&data, i)).collect();
        let completed: Vec<(usize, &[Gf16])> =
            vec![(7, &shares[7][..]), (2, &shares[2][..]), (4, &shares[4][..])];
        let a = code.decode(&completed).unwrap();
        let b = code.decode(&completed).unwrap();
        assert_eq!(a, b);
        let (hits, misses) = code.decode_cache_stats();
        assert_eq!(misses, 1, "first decode populates the cache");
        assert!(hits >= 1, "second decode must be served from cache");
        assert_eq!(code.decode_cache_len(), 1);
    }

    #[test]
    fn prop_cached_decode_equals_fresh_solve() {
        // The cache must be semantically invisible: for random codes and
        // random survivor subsets, a cached decode (second call, same
        // subset) equals a cache-disabled fresh solve.
        prop::check(25, |g| {
            let k = g.usize_in(1, 10);
            let n = k + g.usize_in(0, 12);
            let cached = RsCode::new(n, k).unwrap();
            let fresh = cached.clone().with_decode_cache_capacity(0);
            let stream = g.usize_in(1, 4);
            let data: Vec<Vec<Gf16>> = (0..stream)
                .map(|_| (0..k).map(|_| Gf16(g.u64() as u16)).collect())
                .collect();
            let shares: Vec<Vec<Gf16>> =
                (0..n).map(|i| cached.encode_share(&data, i)).collect();
            for _ in 0..3 {
                let mut order: Vec<usize> = (0..n).collect();
                g.shuffle(&mut order);
                let completed: Vec<(usize, &[Gf16])> =
                    order.iter().take(k).map(|&i| (i, &shares[i][..])).collect();
                // Decode twice on the caching code (second hit comes from
                // the LRU) and once on the cache-free reference.
                let warm = cached.decode(&completed).map_err(|e| e.to_string())?;
                let hit = cached.decode(&completed).map_err(|e| e.to_string())?;
                let reference = fresh.decode(&completed).map_err(|e| e.to_string())?;
                if warm != reference || hit != reference {
                    return Err(format!(
                        "cached decode diverged from fresh solve (n={n} k={k})"
                    ));
                }
            }
            let (_, fresh_misses) = fresh.decode_cache_stats();
            if fresh.decode_cache_len() != 0 || fresh_misses == 0 {
                return Err("capacity-0 cache must stay empty and always miss".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_cache_eviction_never_changes_results() {
        // A capacity-2 cache cycled over >2 subsets evicts constantly;
        // every decode must still equal the uncached reference.
        prop::check(15, |g| {
            let k = g.usize_in(2, 6);
            let n = k + g.usize_in(2, 10);
            let code = RsCode::new(n, k)
                .unwrap()
                .with_decode_cache_capacity(2);
            let reference = code.clone().with_decode_cache_capacity(0);
            let data = vec![(0..k).map(|_| Gf16(g.u64() as u16)).collect::<Vec<_>>()];
            let shares: Vec<Vec<Gf16>> =
                (0..n).map(|i| code.encode_share(&data, i)).collect();
            // Cycle through 5 distinct-ish subsets twice.
            let mut subsets: Vec<Vec<usize>> = Vec::new();
            for _ in 0..5 {
                let mut order: Vec<usize> = (0..n).collect();
                g.shuffle(&mut order);
                subsets.push(order.into_iter().take(k).collect());
            }
            for round in 0..2 {
                for (si, subset) in subsets.iter().enumerate() {
                    let completed: Vec<(usize, &[Gf16])> =
                        subset.iter().map(|&i| (i, &shares[i][..])).collect();
                    let got = code.decode(&completed).map_err(|e| e.to_string())?;
                    let want = reference.decode(&completed).map_err(|e| e.to_string())?;
                    if got != want {
                        return Err(format!(
                            "eviction changed results (round {round}, subset {si})"
                        ));
                    }
                    if code.decode_cache_len() > 2 {
                        return Err(format!(
                            "cache exceeded capacity: {}",
                            code.decode_cache_len()
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn decode_matrix_matches_fresh_inversion() {
        let code = RsCode::new(12, 5).unwrap();
        let rows = [9usize, 0, 3, 11, 6];
        let cached = code.decode_matrix(&rows);
        let fresh = code.invert_rows_fresh(&rows);
        assert_eq!(*cached, fresh);
        // Same subset again: identical Arc contents, one more hit.
        let again = code.decode_matrix(&rows);
        assert_eq!(*again, fresh);
        let (hits, misses) = code.decode_cache_stats();
        assert_eq!((hits, misses), (1, 1));
    }
}
