//! Reed–Solomon (n, k) code over GF(2^16) — exact recovery at BICEC scale.
//!
//! Encode: evaluate the degree-(k-1) polynomial with the data symbols as
//! coefficients at n distinct field points (alpha^0 ... alpha^(n-1)).
//! Decode (no errors, only erasures — finished/unfinished workers): solve
//! the k x k Vandermonde system over the field via Gaussian elimination.
//! n is bounded by the field order; BICEC's n = 3200 is comfortable.
//!
//! Payloads are `u16` symbols; `quantize`/`dequantize` map f32 matrices to
//! symbol streams losslessly enough for verification (12-bit mantissa grid).

use super::gf::Gf16;

#[derive(Debug)]
pub enum RsError {
    NotEnough { have: usize, need: usize },
    DuplicateRow(usize),
    TooLong { n: usize },
}

impl std::fmt::Display for RsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RsError::NotEnough { have, need } => write!(f, "have {have} < k={need} shares"),
            RsError::DuplicateRow(r) => write!(f, "duplicate evaluation row {r}"),
            RsError::TooLong { n } => write!(f, "n={n} exceeds field order - 1"),
        }
    }
}

impl std::error::Error for RsError {}

/// Systematic-free RS code: share i = p(alpha^i), p's coefficients = data.
#[derive(Clone, Debug)]
pub struct RsCode {
    n: usize,
    k: usize,
    /// Evaluation points alpha^i.
    points: Vec<Gf16>,
}

impl RsCode {
    pub fn new(n: usize, k: usize) -> Result<Self, RsError> {
        if n >= (1 << 16) {
            return Err(RsError::TooLong { n });
        }
        assert!(k >= 1 && n >= k, "need n >= k >= 1");
        let a = Gf16::alpha();
        let points = (0..n).map(|i| a.pow(i as u64)).collect();
        Ok(Self { n, k, points })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Encode one share: data is a stream of symbol vectors, each of length
    /// k (one polynomial per stream position). Output has the same stream
    /// length, one symbol per position.
    pub fn encode_share(&self, data: &[Vec<Gf16>], share: usize) -> Vec<Gf16> {
        assert!(share < self.n);
        let x = self.points[share];
        data.iter()
            .map(|coeffs| {
                debug_assert_eq!(coeffs.len(), self.k);
                // Horner at x.
                coeffs.iter().rev().fold(Gf16::ZERO, |acc, &c| acc.mul(x).add(c))
            })
            .collect()
    }

    /// Decode the k data symbols per stream position from k completed
    /// shares `(share_index, symbols)`.
    pub fn decode(
        &self,
        completed: &[(usize, &[Gf16])],
    ) -> Result<Vec<Vec<Gf16>>, RsError> {
        if completed.len() < self.k {
            return Err(RsError::NotEnough { have: completed.len(), need: self.k });
        }
        let used = &completed[..self.k];
        {
            let mut seen = std::collections::HashSet::new();
            for (i, _) in used {
                if !seen.insert(*i) {
                    return Err(RsError::DuplicateRow(*i));
                }
            }
        }
        let k = self.k;
        let stream_len = used[0].1.len();
        assert!(used.iter().all(|(_, s)| s.len() == stream_len));

        // Invert the k x k Vandermonde over GF via Gauss–Jordan once, then
        // apply to every stream position (same structure as the real decode).
        let mut aug: Vec<Gf16> = Vec::with_capacity(k * 2 * k);
        for (i, _) in used {
            let x = self.points[*i];
            let mut p = Gf16::ONE;
            for _ in 0..k {
                aug.push(p);
                p = p.mul(x);
            }
            // identity part appended after, filled below
            for _ in 0..k {
                aug.push(Gf16::ZERO);
            }
        }
        let w = 2 * k;
        for r in 0..k {
            aug[r * w + k + r] = Gf16::ONE;
        }
        // Gauss–Jordan (field is exact; any nonzero pivot works, and
        // distinct points guarantee invertibility).
        for col in 0..k {
            let pivot_row = (col..k)
                .find(|&r| aug[r * w + col] != Gf16::ZERO)
                .expect("Vandermonde over distinct points is invertible");
            if pivot_row != col {
                for j in 0..w {
                    aug.swap(col * w + j, pivot_row * w + j);
                }
            }
            let inv = aug[col * w + col].inv();
            for j in 0..w {
                aug[col * w + j] = aug[col * w + j].mul(inv);
            }
            for r in 0..k {
                if r != col && aug[r * w + col] != Gf16::ZERO {
                    let f = aug[r * w + col];
                    for j in 0..w {
                        let sub = f.mul(aug[col * w + j]);
                        aug[r * w + j] = aug[r * w + j].add(sub);
                    }
                }
            }
        }

        // out[j][pos] = Σ_l inv[j][l] · used[l][pos]
        let mut out = vec![vec![Gf16::ZERO; stream_len]; k];
        for (j, row) in out.iter_mut().enumerate() {
            for (l, (_, sym)) in used.iter().enumerate() {
                let c = aug[j * w + k + l];
                if c == Gf16::ZERO {
                    continue;
                }
                for (o, &s) in row.iter_mut().zip(sym.iter()) {
                    *o = o.add(c.mul(s));
                }
            }
        }
        Ok(out)
    }
}

/// Quantise f32 values into u16 symbols on a uniform grid over
/// [-scale, scale]. Round-trips with absolute error <= scale / 65535.
pub fn quantize(values: &[f32], scale: f32) -> Vec<Gf16> {
    assert!(scale > 0.0);
    values
        .iter()
        .map(|&v| {
            let clamped = v.clamp(-scale, scale);
            let t = (clamped + scale) / (2.0 * scale); // [0, 1]
            Gf16((t * 65535.0).round() as u16)
        })
        .collect()
}

/// Inverse of `quantize`.
pub fn dequantize(symbols: &[Gf16], scale: f32) -> Vec<f32> {
    symbols
        .iter()
        .map(|s| (s.0 as f32 / 65535.0) * 2.0 * scale - scale)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    fn sym(v: u16) -> Gf16 {
        Gf16(v)
    }

    #[test]
    fn encode_decode_small() {
        let code = RsCode::new(6, 3).unwrap();
        let data = vec![
            vec![sym(1), sym(2), sym(3)],
            vec![sym(100), sym(200), sym(300)],
        ];
        let shares: Vec<Vec<Gf16>> =
            (0..6).map(|i| code.encode_share(&data, i)).collect();
        let completed: Vec<(usize, &[Gf16])> =
            vec![(5, &shares[5][..]), (1, &shares[1][..]), (3, &shares[3][..])];
        let decoded = code.decode(&completed).unwrap();
        // decoded[j][pos] must equal data[pos][j]
        for pos in 0..2 {
            for j in 0..3 {
                assert_eq!(decoded[j][pos], data[pos][j], "pos={pos} j={j}");
            }
        }
    }

    #[test]
    fn decode_rejects_duplicates_and_shortage() {
        let code = RsCode::new(4, 2).unwrap();
        let data = vec![vec![sym(7), sym(9)]];
        let s0 = code.encode_share(&data, 0);
        assert!(matches!(
            code.decode(&[(0, &s0[..])]),
            Err(RsError::NotEnough { .. })
        ));
        assert!(matches!(
            code.decode(&[(0, &s0[..]), (0, &s0[..])]),
            Err(RsError::DuplicateRow(0))
        ));
    }

    #[test]
    fn prop_any_k_subset_recovers() {
        prop::check(30, |g| {
            let k = g.usize_in(1, 12);
            let n = k + g.usize_in(0, 20);
            let code = RsCode::new(n, k).unwrap();
            let stream = g.usize_in(1, 8);
            let data: Vec<Vec<Gf16>> = (0..stream)
                .map(|_| (0..k).map(|_| Gf16(g.u64() as u16)).collect())
                .collect();
            let shares: Vec<Vec<Gf16>> =
                (0..n).map(|i| code.encode_share(&data, i)).collect();
            let mut order: Vec<usize> = (0..n).collect();
            g.shuffle(&mut order);
            let completed: Vec<(usize, &[Gf16])> =
                order.iter().take(k).map(|&i| (i, &shares[i][..])).collect();
            let decoded = code.decode(&completed).map_err(|e| e.to_string())?;
            for pos in 0..stream {
                for j in 0..k {
                    if decoded[j][pos] != data[pos][j] {
                        return Err(format!("mismatch at pos={pos} j={j} (n={n} k={k})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bicec_scale_code_constructs_and_decodes() {
        // The paper's BICEC configuration: (3200, 800). Exactness at scale.
        let code = RsCode::new(3200, 800).unwrap();
        let data: Vec<Vec<Gf16>> = vec![(0..800).map(|i| Gf16(i as u16 * 7 + 1)).collect()];
        // Encode a scattered subset of shares and decode from them.
        let subset: Vec<usize> = (0..800).map(|i| i * 4 % 3200 + i / 800).collect();
        let shares: Vec<Vec<Gf16>> =
            subset.iter().map(|&i| code.encode_share(&data, i)).collect();
        let completed: Vec<(usize, &[Gf16])> = subset
            .iter()
            .zip(shares.iter())
            .map(|(&i, s)| (i, &s[..]))
            .collect();
        let decoded = code.decode(&completed).unwrap();
        for j in 0..800 {
            assert_eq!(decoded[j][0], data[0][j]);
        }
    }

    #[test]
    fn quantize_round_trip_error_bound() {
        let vals = [-1.0f32, -0.5, 0.0, 0.25, 0.999, 1.0];
        let q = quantize(&vals, 1.0);
        let back = dequantize(&q, 1.0);
        for (v, b) in vals.iter().zip(&back) {
            assert!((v - b).abs() <= 1.0 / 65535.0 + 1e-7, "{v} vs {b}");
        }
    }
}
