//! Real Vandermonde generator matrices.
//!
//! The paper encodes with `Â_n = A_1 + n·A_2` style polynomial evaluation at
//! integer points. Integer nodes make the K x K decode submatrices blow up
//! (cond grows super-exponentially), so the real-valued code here evaluates
//! at Chebyshev points on [-1, 1] — the standard fix in real-number coded
//! computing. Decode quality is monitored via `LuFactors::cond_estimate`.

use crate::linalg::LuFactors;

/// Chebyshev nodes of the first kind: x_i = cos((2i+1)π / 2n), i ∈ [0, n).
/// Distinct for any n, clustered toward ±1.
pub fn chebyshev_points(n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| ((2 * i + 1) as f64 * std::f64::consts::PI / (2 * n) as f64).cos())
        .collect()
}

/// Row-major (rows x k) Vandermonde: out[i][j] = points[i]^j.
pub fn vandermonde(points: &[f64], k: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(points.len() * k);
    for &x in points {
        let mut p = 1.0;
        for _ in 0..k {
            out.push(p);
            p *= x;
        }
    }
    out
}

/// An (n, k) Vandermonde generator with helpers for submatrix decode.
#[derive(Clone, Debug)]
pub struct Vandermonde {
    n: usize,
    k: usize,
    points: Vec<f64>,
    /// Row-major (n x k) generator.
    gen: Vec<f64>,
}

impl Vandermonde {
    /// Chebyshev-point generator, the default for all real codes.
    pub fn chebyshev(n: usize, k: usize) -> Self {
        assert!(k >= 1 && n >= k, "need n >= k >= 1, got n={n} k={k}");
        let points = chebyshev_points(n);
        let gen = vandermonde(&points, k);
        Self { n, k, points, gen }
    }

    /// Integer-point generator (1, 2, ..., n) — the paper's literal
    /// construction; exposed for the conditioning ablation.
    pub fn integer_points(n: usize, k: usize) -> Self {
        assert!(k >= 1 && n >= k);
        let points: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let gen = vandermonde(&points, k);
        Self { n, k, points, gen }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn points(&self) -> &[f64] {
        &self.points
    }

    /// Generator row for encoded block `i` (length k).
    pub fn row(&self, i: usize) -> &[f64] {
        &self.gen[i * self.k..(i + 1) * self.k]
    }

    /// Row-major (k x k) submatrix of the rows in `subset`.
    pub fn submatrix(&self, subset: &[usize]) -> Vec<f64> {
        assert_eq!(subset.len(), self.k, "need exactly k rows");
        let mut out = Vec::with_capacity(self.k * self.k);
        for &r in subset {
            assert!(r < self.n, "row {r} out of range (n={})", self.n);
            out.extend_from_slice(self.row(r));
        }
        out
    }

    /// LU-factor the decode submatrix for the completed subset.
    pub fn factor_subset(&self, subset: &[usize]) -> Result<LuFactors, crate::linalg::LuError> {
        LuFactors::factor(self.k, &self.submatrix(subset))
    }

    /// Inverse of the decode submatrix, row-major k x k.
    pub fn invert_subset(&self, subset: &[usize]) -> Result<Vec<f64>, crate::linalg::LuError> {
        Ok(self.factor_subset(subset)?.inverse())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn chebyshev_points_distinct_and_bounded() {
        let pts = chebyshev_points(40);
        for w in pts.windows(2) {
            assert!(w[0] > w[1], "points must be strictly decreasing");
        }
        assert!(pts.iter().all(|p| p.abs() < 1.0));
    }

    #[test]
    fn generator_row_is_powers() {
        let v = Vandermonde::chebyshev(4, 3);
        let x = v.points()[2];
        let row = v.row(2);
        assert!((row[0] - 1.0).abs() < 1e-15);
        assert!((row[1] - x).abs() < 1e-15);
        assert!((row[2] - x * x).abs() < 1e-15);
    }

    #[test]
    fn any_k_subset_invertible() {
        let v = Vandermonde::chebyshev(12, 5);
        // a few deliberately adversarial subsets
        for subset in [
            vec![0, 1, 2, 3, 4],
            vec![7, 8, 9, 10, 11],
            vec![0, 3, 6, 9, 11],
            vec![11, 0, 5, 2, 8], // unsorted
        ] {
            let f = v.factor_subset(&subset).expect("must factor");
            assert!(f.cond_estimate().is_finite());
        }
    }

    #[test]
    fn chebyshev_conditioning_beats_integer_points() {
        // Compare true inf-norm conditions of the worst (trailing) subset.
        let k = 10;
        let cond_inf = |v: &Vandermonde, subset: &[usize]| -> f64 {
            let sub = v.submatrix(subset);
            let inv = v.factor_subset(subset).unwrap().inverse();
            let norm = |m: &[f64]| {
                (0..k)
                    .map(|i| m[i * k..(i + 1) * k].iter().map(|x| x.abs()).sum::<f64>())
                    .fold(0.0, f64::max)
            };
            norm(&sub) * norm(&inv)
        };
        let che = Vandermonde::chebyshev(40, k);
        let int = Vandermonde::integer_points(40, k);
        let worst: Vec<usize> = (30..40).collect();
        let c_che = cond_inf(&che, &worst);
        let c_int = cond_inf(&int, &worst);
        assert!(
            c_che < c_int / 1e3,
            "chebyshev {c_che:.3e} should be far better than integer {c_int:.3e}"
        );
    }

    #[test]
    fn prop_subset_decode_recovers_polynomial() {
        // Encoding a polynomial's coefficients then solving any k-subset
        // must return the coefficients.
        prop::check(40, |g| {
            let k = g.usize_in(1, 10);
            let n = k + g.usize_in(0, 10);
            let v = Vandermonde::chebyshev(n, k);
            let coeffs: Vec<f64> = (0..k).map(|_| g.f64_in(-2.0, 2.0)).collect();
            // encoded value at row i = sum_j coeffs[j] * gen[i][j]
            let encoded: Vec<f64> = (0..n)
                .map(|i| v.row(i).iter().zip(&coeffs).map(|(a, c)| a * c).sum())
                .collect();
            let mut rows: Vec<usize> = (0..n).collect();
            g.shuffle(&mut rows);
            let subset: Vec<usize> = rows.into_iter().take(k).collect();
            let f = v.factor_subset(&subset).map_err(|e| e.to_string())?;
            let rhs: Vec<f64> = subset.iter().map(|&i| encoded[i]).collect();
            let got = f.solve_vec(&rhs);
            let err = got
                .iter()
                .zip(&coeffs)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            if err < 1e-6 {
                Ok(())
            } else {
                Err(format!("recovery error {err:.3e} (k={k}, n={n})"))
            }
        });
    }
}
