//! Offline shim for the `anyhow` API surface `hcec` uses.
//!
//! The build environment has no crates.io access, so this path dependency
//! provides: [`Error`], [`Result`], the `anyhow!` / `bail!` / `ensure!`
//! macros, and the [`Context`] extension trait. Errors are stored as
//! rendered strings (context is prepended `{context}: {cause}` like the
//! real crate's display chain). Deliberately not implemented: backtraces,
//! downcasting, `Chain`.
//!
//! Like the real crate, `Error` does NOT implement `std::error::Error` —
//! that is what makes the blanket `From<E: std::error::Error>` impl
//! coherent, so `?` converts any std error into `anyhow::Error`.

use std::fmt;

/// A rendered, type-erased error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the real crate's `Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }

    /// Prepend a context layer, mirroring `anyhow`'s `{context}: {cause}`
    /// display of a context chain.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let text = std::fs::read_to_string("/definitely/not/a/path")
            .with_context(|| "reading config".to_string())?;
        Ok(text)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(err.to_string().starts_with("reading config: "));
    }

    #[test]
    fn macros_build_errors() {
        let name = "x";
        let e = anyhow!("missing artifact {name:?}");
        assert_eq!(e.to_string(), "missing artifact \"x\"");
        let e2: Error = anyhow!(std::fmt::Error);
        assert!(!e2.to_string().is_empty());
    }

    #[test]
    fn bail_and_ensure() {
        fn f(flag: bool) -> Result<()> {
            ensure!(flag, "flag was {flag}");
            bail!("always fails after ensure");
        }
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(f(true).unwrap_err().to_string(), "always fails after ensure");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("empty").unwrap_err();
        assert_eq!(err.to_string(), "empty");
    }
}
