//! Fig. 2b — average decoding time vs N for (2400,2400,2400) and
//! (2400,960,6000).
//!
//! Paper shape: BICEC decode >> CEC = MLCEC (both negligible); decode
//! grows with v (the tall x fat case is slower); decode is ~flat in N.

use hcec::bench::{header, Bench};
use hcec::codes::RealMdsCode;
use hcec::config::ExperimentConfig;
use hcec::figures::fig2_table;
use hcec::linalg::Matrix;
use hcec::metrics::write_csv;
use hcec::rng::default_rng;

fn trials() -> usize {
    std::env::var("HCEC_BENCH_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(20)
}

fn main() {
    header("fig2b_decode");
    let cfg = ExperimentConfig { trials: trials(), ..Default::default() };
    let sq = fig2_table(&cfg, "2b");
    println!("square (2400,2400,2400):\n{}", sq.render());
    let tf_cfg = cfg.clone().tall_fat();
    let tf = fig2_table(&tf_cfg, "2b");
    println!("tall x fat (2400,960,6000):\n{}", tf.render());
    println!("paper: BICEC decode dominates; larger v decodes slower.\n");
    let _ = write_csv(&sq, "results/fig2b_square.csv");
    let _ = write_csv(&tf, "results/fig2b_tallfat.csv");

    // Real decode cost at end-to-end scale: the K-way combine is the hot
    // part; the K x K inverse is amortised.
    println!("native decode micro-bench (end-to-end scale):");
    let mut rng = default_rng(2);
    let code = RealMdsCode::new(12, 10);
    let data: Vec<Matrix> = (0..10).map(|_| Matrix::random(24, 240, &mut rng)).collect();
    let coded = code.encode(&data);
    let completed: Vec<(usize, &Matrix)> = (2..12).map(|i| (i, &coded[i])).collect();
    Bench::new("decode k10 blocks 24x240")
        .run(|| code.decode(&completed).unwrap())
        .print();
    Bench::new("decode_coeffs only (inverse)")
        .run(|| code.decode_coeffs_f32(&[2, 3, 4, 5, 6, 7, 8, 9, 10, 11]).unwrap())
        .print();
}
