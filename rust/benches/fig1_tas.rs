//! Bench + regeneration for Fig. 1: the TAS grids at N ∈ {8, 6, 4}.
//!
//! Correctness of the exact paper layouts is asserted in unit tests
//! (tas::mlcec, figures::fig1); this target regenerates the figure and
//! times allocation construction (the operation a master performs at every
//! elastic event, so it must be cheap).

use hcec::bench::{header, Bench};
use hcec::figures::{fig1_grid, fig1_table};
use hcec::tas::{Bicec, Cec, Mlcec, Scheme};

fn main() {
    header("fig1_tas");
    for n in [8, 6, 4] {
        println!("{}", fig1_grid(n));
    }
    println!("{}", fig1_table().render());

    println!("allocation construction cost (per elastic event):");
    Bench::new("cec_allocate_n40").run(|| Cec::new(10, 20).allocate(40)).print();
    Bench::new("mlcec_allocate_n40 (Alg 1)")
        .run(|| Mlcec::new(10, 20).allocate(40))
        .print();
    Bench::new("bicec_allocate_n40").run(|| Bicec::new(800, 80, 40).allocate(40)).print();
    Bench::new("mlcec_allocate_n8_fig1").run(|| Mlcec::new(2, 4).allocate(8)).print();
}
