//! Fig. 2a — average computation time vs N (uwv = 2400^3).
//!
//! Paper shape to reproduce: BICEC < MLCEC < CEC for all N, BICEC ≈ 85%
//! better than CEC at N = 40; times fall with N for every scheme.

use hcec::bench::{header, Bench};
use hcec::config::ExperimentConfig;
use hcec::figures::fig2_table;
use hcec::metrics::write_csv;
use hcec::rng::default_rng;
use hcec::sim::{simulate_static, CostModel, SpeedModel, WorkerSpeeds};
use hcec::tas::Cec;

fn trials() -> usize {
    std::env::var("HCEC_BENCH_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(20)
}

fn main() {
    header("fig2a_compute");
    let cfg = ExperimentConfig { trials: trials(), ..Default::default() };
    let table = fig2_table(&cfg, "2a");
    println!("{}", table.render());
    println!("paper: BICEC -85% vs CEC at N=40; MLCEC between.\n");
    let _ = write_csv(&table, "results/fig2a.csv");

    println!("simulator hot path:");
    let cost = CostModel::paper_default();
    let job = cfg.job;
    let mut rng = default_rng(1);
    let speeds = WorkerSpeeds::sample(&SpeedModel::paper_default(), 40, &mut rng);
    let cec = Cec::new(10, 20);
    Bench::new("simulate_static cec n40")
        .run(|| simulate_static(&cec, 40, job, &cost, &speeds))
        .print();
}
