//! Fig. 2c — average finishing time vs N, square (2400,2400,2400).
//!
//! Paper headline: BICEC is best everywhere and ~45% better than CEC at
//! N = 40 (computation gain minus its heavy decode).

use hcec::bench::header;
use hcec::config::ExperimentConfig;
use hcec::figures::fig2_table;
use hcec::metrics::write_csv;

fn trials() -> usize {
    std::env::var("HCEC_BENCH_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(20)
}

fn main() {
    header("fig2c_finish_square");
    let cfg = ExperimentConfig { trials: trials(), ..Default::default() };
    let table = fig2_table(&cfg, "2c");
    println!("{}", table.render());
    println!("paper: BICEC best for all N; -45% vs CEC at N=40.");
    let _ = write_csv(&table, "results/fig2c.csv");
}
