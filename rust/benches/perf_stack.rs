//! §Perf — whole-stack micro-benchmarks. Before/after numbers for each
//! optimisation pass are recorded in rust/EXPERIMENTS.md §Perf, and every
//! run emits machine-readable `BENCH_perf_stack.json` (repo root, override
//! with `HCEC_BENCH_JSON`) so the perf trajectory is tracked across PRs.
//!
//! L3 targets (rust/EXPERIMENTS.md §Perf-targets): DES >= 1e6
//! subtask-events/s; allocation-free event hot loop; decode dominated by
//! the K·u·v combine, not the K x K solve; PJRT execute latency small vs a
//! 240-scale subtask.
//!
//! Experiment-shaped rows (the Monte-Carlo batches) are constructed via
//! `scenario::Scenario` + `Engine::run` — the same surface the figures and
//! CLI use, so a bench row IS a reproducible scenario. Single-call rows
//! (one DES run, gemm, codec, decode) stay raw micro-benchmarks of the
//! hot paths underneath that surface.
//!
//! CI smoke: `HCEC_BENCH_QUICK=1` shrinks the sampling windows ~20x.

use hcec::bench::{header, Bench, BenchResult, JsonReport};
use hcec::codes::simd::{
    active_tier, addmul_slice_tier, detected_tier, dot_tier, mul_slice_tier,
    poly_eval_tile_tier, Tier,
};
use hcec::codes::{discrete_log, Gf16, RealMdsCode};
use hcec::linalg::{gemm, gemm_naive, gemm_packed, gemm_single_thread, Matrix};
use hcec::rng::{default_rng, Rng};
use hcec::runtime::{artifacts_available, default_artifact_dir, Runtime};
use hcec::scenario::{
    ElasticitySpec, Engine, Scenario, SchemeConfig, SeedMode,
};
use hcec::sim::{
    simulate_static, CostModel, ElasticTrace, Reassign, SpeedModel, TraceSimulator,
    WorkerSpeeds,
};
use hcec::tas::{Bicec, Cec, Mlcec, Scheme};
use hcec::workload::JobSpec;

fn events_per_sec(r: &BenchResult, events: f64) -> f64 {
    events / r.summary.mean
}

fn main() {
    header("perf_stack");
    let mut report = JsonReport::new("perf_stack");
    let cost = CostModel::paper_default();
    let job = JobSpec::paper_square();
    let mut rng = default_rng(3);
    let speeds = WorkerSpeeds::sample(&SpeedModel::paper_default(), 40, &mut rng);

    println!("-- L3: DES hot path --");
    let cec = Cec::new(10, 20);
    let mlcec = Mlcec::new(10, 20);
    let bicec = Bicec::new(800, 80, 40);
    // One static run processes N*S (CEC/MLCEC) or N*S_b (BICEC) events.
    let r = Bench::new("simulate_static cec n40").run(|| simulate_static(&cec, 40, job, &cost, &speeds));
    r.print();
    println!("    -> {:.2e} subtask-events/s (target >= 1e6)", events_per_sec(&r, 800.0));
    report.push(&r, &[("subtask_events_per_sec", events_per_sec(&r, 800.0))]);
    let r = Bench::new("simulate_static mlcec n40").run(|| simulate_static(&mlcec, 40, job, &cost, &speeds));
    r.print();
    report.push(&r, &[("subtask_events_per_sec", events_per_sec(&r, 800.0))]);
    let r = Bench::new("simulate_static bicec n40").run(|| simulate_static(&bicec, 40, job, &cost, &speeds));
    r.print();
    println!("    -> {:.2e} subtask-events/s", events_per_sec(&r, 3200.0));
    report.push(&r, &[("subtask_events_per_sec", events_per_sec(&r, 3200.0))]);

    // Batch driver through the unified scenario surface: allocation +
    // scratch amortised across a 32-trial sweep (the Monte-Carlo shape
    // every figure actually runs). Engine::run includes the per-trial
    // speed sampling — negligible next to the DES itself.
    let sweep_sc = Scenario::builder("bench_static_bicec_n40")
        .engine(Engine::Statics)
        .job(job)
        .fleet(40, 40)
        .schemes(vec![SchemeConfig::Bicec { k: 800, s_per_worker: 80 }])
        .trials(32)
        .seed(3)
        .build()
        .expect("valid bench scenario");
    let r = Bench::new("scenario statics bicec n40 x32")
        .run(|| sweep_sc.run().expect("statics engine cannot fail"));
    r.print();
    println!(
        "    -> {:.2e} subtask-events/s (amortised)",
        events_per_sec(&r, 32.0 * 3200.0)
    );
    report.push(&r, &[("subtask_events_per_sec", events_per_sec(&r, 32.0 * 3200.0))]);

    println!("\n-- L3: elastic simulator (interval tracking) --");
    let small_job = JobSpec::new(240, 240, 240);
    let speeds8 = WorkerSpeeds::sample(&SpeedModel::paper_default(), 8, &mut rng);
    let tau = cost.worker_time(small_job.ops() / 16, 1.0);
    let trace = ElasticTrace::fig1(1.5 * tau, 3.0 * tau);
    let cec_small = Cec::new(2, 4);
    let r = Bench::new("simulate_trace cec fig1")
        .run(|| hcec::sim::simulate_trace(&cec_small, &trace, small_job, &cost, &speeds8).unwrap());
    r.print();
    report.push(&r, &[]);
    // Reused simulator: the allocation-free steady state.
    let mut tsim = TraceSimulator::new(&cec_small);
    let r = Bench::new("simulate_trace cec fig1 (reused sim)").run(|| {
        tsim.run(&trace, small_job, &cost, &speeds8, hcec::sim::Reassign::Identity).unwrap()
    });
    r.print();
    report.push(&r, &[]);

    println!("\n-- L3: allocation (runs at every elastic event) --");
    let r = Bench::new("mlcec allocate n40").run(|| mlcec.allocate(40));
    r.print();
    report.push(&r, &[]);

    println!("\n-- master decode: combine vs inverse split --");
    let code = RealMdsCode::new(12, 10);
    let data: Vec<Matrix> = (0..10).map(|_| Matrix::random(24, 240, &mut rng)).collect();
    let coded = code.encode(&data);
    let completed: Vec<(usize, &Matrix)> = (2..12).map(|i| (i, &coded[i])).collect();
    // Share metric measured on the cache-DISABLED code so both timings
    // cover the same pipeline (inverse + combine vs inverse only); the
    // cached decode is reported separately to show the LRU amortisation.
    let uncached = code.clone().with_inverse_cache_capacity(0);
    let r_dec = Bench::new("decode k10 (fresh inv + combine)").run(|| uncached.decode(&completed).unwrap());
    r_dec.print();
    report.push(&r_dec, &[]);
    let subset: Vec<usize> = (2..12).collect();
    let r_inv = Bench::new("inverse only (fresh)").run(|| uncached.decode_coeffs_f32(&subset).unwrap());
    r_inv.print();
    println!(
        "    -> combine share of decode: {:.1}% (target: dominant)",
        100.0 * (1.0 - r_inv.summary.mean / r_dec.summary.mean)
    );
    report.push(&r_inv, &[]);
    let r_hot = Bench::new("decode k10 (LRU-cached inv)").run(|| code.decode(&completed).unwrap());
    r_hot.print();
    println!(
        "    -> cached decode at {:.1}% of fresh (inverse amortised by the LRU)",
        100.0 * r_hot.summary.mean / r_dec.summary.mean
    );
    report.push(&r_hot, &[]);

    println!("\n-- worker hot path: native gemm --");
    let a = Matrix::random(2, 240, &mut rng);
    let b = Matrix::random(240, 240, &mut rng);
    let r = Bench::new("gemm blocked 2x240x240").run(|| gemm(&a, &b));
    r.print();
    println!("    -> {:.2} Gmac/s", 2.0 * 240.0 * 240.0 / r.summary.mean / 1e9);
    report.push(&r, &[("gmacs", 2.0 * 240.0 * 240.0 / r.summary.mean / 1e9)]);
    let r = Bench::new("gemm naive   2x240x240").run(|| gemm_naive(&a, &b));
    r.print();
    report.push(&r, &[]);
    let a2 = Matrix::random(240, 240, &mut rng);
    let r = Bench::new("gemm blocked 240x240x240").run(|| gemm(&a2, &b));
    r.print();
    println!("    -> {:.2} Gmac/s (parallel)", 240.0f64.powi(3) / r.summary.mean / 1e9);
    report.push(&r, &[("gmacs", 240.0f64.powi(3) / r.summary.mean / 1e9)]);
    let r = Bench::new("gemm 1-thread 240x240x240").run(|| gemm_single_thread(&a2, &b));
    r.print();
    println!("    -> {:.2} Gmac/s (micro-kernel only)", 240.0f64.powi(3) / r.summary.mean / 1e9);
    report.push(&r, &[("gmacs", 240.0f64.powi(3) / r.summary.mean / 1e9)]);
    // Packed + dispatched single-thread kernel (what cluster/pool workers
    // run). Its scalar pair is the "gemm 1-thread" oracle row above — both
    // are bit-identical by construction, so the delta is pure kernel speed.
    let r = Bench::new("gemm packed 240x240x240").run(|| gemm_packed(&a2, &b));
    r.print();
    println!(
        "    -> {:.2} Gmac/s (packed, {} tier)",
        240.0f64.powi(3) / r.summary.mean / 1e9,
        active_tier().name()
    );
    report.push(&r, &[("gmacs", 240.0f64.powi(3) / r.summary.mean / 1e9)]);

    println!("\n-- exact codec: bulk GF(2^16) kernels --");
    println!(
        "(dispatch: detected tier {}, active tier {} — set HCEC_FORCE_SCALAR=1 to pin the oracle)",
        detected_tier().name(),
        active_tier().name()
    );
    // Paired scalar-vs-SIMD rows on the same 64 KiB symbol buffer (32768
    // Gf16). Tier-explicit kernel calls sidestep the dispatch thresholds
    // and the process-global HCEC_FORCE_SCALAR knob, so both arms of each
    // pair are measured in one run; the "simd" arm runs the detected tier
    // (on a scalar-only host both arms measure the oracle — see the tier
    // line above).
    let tier = detected_tier();
    let nsym = 32 * 1024usize;
    let base: Vec<Gf16> = (0..nsym).map(|_| Gf16(rng.next_u64() as u16)).collect();
    let c = Gf16(0x1234);
    let mut buf = base.clone();
    let r = Bench::new("gf mul_slice 64KiB scalar")
        .run(|| mul_slice_tier(Tier::Scalar, c, &mut buf));
    r.print();
    report.push(&r, &[("symbol_macs_per_sec", nsym as f64 / r.summary.mean)]);
    let mut buf = base.clone();
    let r = Bench::new("gf mul_slice 64KiB simd").run(|| mul_slice_tier(tier, c, &mut buf));
    r.print();
    println!("    -> {:.2e} symbol-MACs/s", nsym as f64 / r.summary.mean);
    report.push(&r, &[("symbol_macs_per_sec", nsym as f64 / r.summary.mean)]);
    let mut acc = vec![Gf16::ZERO; nsym];
    let r = Bench::new("gf addmul_slice 64KiB scalar")
        .run(|| addmul_slice_tier(Tier::Scalar, &mut acc, c, &base));
    r.print();
    report.push(&r, &[("symbol_macs_per_sec", nsym as f64 / r.summary.mean)]);
    let mut acc = vec![Gf16::ZERO; nsym];
    let r = Bench::new("gf addmul_slice 64KiB simd")
        .run(|| addmul_slice_tier(tier, &mut acc, c, &base));
    r.print();
    println!("    -> {:.2e} symbol-MACs/s", nsym as f64 / r.summary.mean);
    report.push(&r, &[("symbol_macs_per_sec", nsym as f64 / r.summary.mean)]);
    // The decode/encode inner loop: one k=800 polynomial against a 32-wide
    // tile of evaluation points (the ENCODE_TILE shape), and the k=800 dot.
    let kk = 800usize;
    let coeffs: Vec<Gf16> = (0..kk).map(|_| Gf16(rng.next_u64() as u16)).collect();
    let tile = 32usize;
    let mut lpow = vec![0u16; kk * tile];
    for t in 0..tile {
        let lx = discrete_log(Gf16(t as u16 + 1)) as u32;
        let mut cur = 0u32;
        for l in 0..kk {
            lpow[l * tile + t] = cur as u16;
            cur += lx;
            if cur >= 65535 {
                cur -= 65535;
            }
        }
    }
    let mut out = vec![Gf16::ZERO; tile];
    let r = Bench::new("gf poly_eval_tile k800 t32 scalar")
        .run(|| poly_eval_tile_tier(Tier::Scalar, &coeffs, &lpow, tile, &mut out));
    r.print();
    report.push(&r, &[("symbol_macs_per_sec", (kk * tile) as f64 / r.summary.mean)]);
    let mut out = vec![Gf16::ZERO; tile];
    let r = Bench::new("gf poly_eval_tile k800 t32 simd")
        .run(|| poly_eval_tile_tier(tier, &coeffs, &lpow, tile, &mut out));
    r.print();
    println!("    -> {:.2e} symbol-MACs/s", (kk * tile) as f64 / r.summary.mean);
    report.push(&r, &[("symbol_macs_per_sec", (kk * tile) as f64 / r.summary.mean)]);
    let va: Vec<Gf16> = (0..kk).map(|_| Gf16(rng.next_u64() as u16)).collect();
    let vb: Vec<Gf16> = (0..kk).map(|_| Gf16(rng.next_u64() as u16)).collect();
    let r = Bench::new("gf dot k800 scalar").run(|| dot_tier(Tier::Scalar, &va, &vb));
    r.print();
    report.push(&r, &[("symbol_macs_per_sec", kk as f64 / r.summary.mean)]);
    let r = Bench::new("gf dot k800 simd").run(|| dot_tier(tier, &va, &vb));
    r.print();
    report.push(&r, &[("symbol_macs_per_sec", kk as f64 / r.summary.mean)]);

    let rs = hcec::codes::RsCode::new(3200, 800).unwrap();
    let stream = 64usize;
    let gf_data: Vec<Vec<Gf16>> = (0..stream)
        .map(|_| (0..800).map(|_| Gf16(rng.next_u64() as u16)).collect())
        .collect();
    let r = Bench::new("rs encode_share k800 x64").run(|| rs.encode_share(&gf_data, 17));
    r.print();
    println!(
        "    -> {:.2e} symbol-MACs/s",
        800.0 * stream as f64 / r.summary.mean
    );
    report.push(&r, &[("symbol_macs_per_sec", 800.0 * stream as f64 / r.summary.mean)]);
    // Tiled multi-share encode through the dispatched kernels: 64 shares =
    // two full ENCODE_TILE=32 passes over the data, the shape of the
    // (800, 3200) encode sweep. Its scalar pair is a HCEC_FORCE_SCALAR=1
    // run of this same row (the knob is process-global).
    let share_ids: Vec<usize> = (0..64).map(|i| i * 47 + 17).collect();
    let r = Bench::new("rs encode_shares k800 x64 simd")
        .run(|| rs.encode_shares(&gf_data, &share_ids));
    r.print();
    let tiled_macs = 64.0 * 800.0 * stream as f64;
    println!("    -> {:.2e} symbol-MACs/s (tiled)", tiled_macs / r.summary.mean);
    report.push(&r, &[("symbol_macs_per_sec", tiled_macs / r.summary.mean)]);

    println!(
        "\n-- N-sweep: deterministic parallel Monte-Carlo ({} thread budget) --",
        hcec::threads::max_threads()
    );
    // Quick mode trims the grid: one N=2560 trace trial costs whole
    // seconds, which would defeat the smoke's ~20x shrink. The large-N
    // rows belong to full (baseline) runs only.
    let sweep_ns: &[usize] = if hcec::bench::quick_mode() {
        println!("(quick mode: N-sweep limited to {{40, 160}}; run without HCEC_BENCH_QUICK for the full grid)");
        &[40, 160]
    } else {
        &[40, 160, 640, 2560]
    };
    for &n in sweep_ns {
        let cec_n = Cec::new(10, 20);
        let trials = 32;
        // Counter-derived per-trial streams (SeedMode::PerTrial keyed at
        // seed 11 — the exact pre-Scenario derivation): the sweep inputs
        // are reproducible regardless of thread count or trial order.
        let static_sc = Scenario::builder(&format!("bench_mc_static_n{n}"))
            .engine(Engine::Statics)
            .job(job)
            .fleet(n, n)
            .schemes(vec![SchemeConfig::Cec { k: 10, s: 20 }])
            .trials(trials)
            .seed(11)
            .seed_mode(SeedMode::PerTrial)
            .build()
            .expect("valid static sweep scenario");
        let r = Bench::new(format!("mc static cec n{n} x{trials}"))
            .run(|| static_sc.run().expect("statics engine cannot fail"));
        r.print();
        let events = (trials * n * 20) as f64;
        println!("    -> {:.2e} subtask-events/s", events_per_sec(&r, events));
        report.push(
            &r,
            &[("n", n as f64), ("subtask_events_per_sec", events_per_sec(&r, events))],
        );

        // Elastic churn scaled with the fleet: fixed per-node event rate,
        // horizon tracking the (shrinking) run length; trace trials taper
        // with N to keep the smoke affordable.
        let tau_n = cost.worker_time(cec_n.subtask_ops(job.u, job.w, job.v, n), 1.0);
        let horizon = 2.0 * 20.0 * tau_n;
        let trace_trials = match n {
            40 => 16,
            160 => 8,
            640 => 4,
            _ => 2,
        };
        let trace_sc = Scenario::builder(&format!("bench_mc_trace_n{n}"))
            .engine(Engine::Trace)
            .job(job)
            .fleet(n, n)
            .schemes(vec![SchemeConfig::Cec { k: 10, s: 20 }])
            .elasticity(ElasticitySpec::Churn {
                n_min: (n / 2).max(20),
                n_initial: n,
                rate: 0.25 * n as f64 / horizon,
                horizon,
                reassign: Reassign::Identity,
            })
            .trials(trace_trials)
            .seed(12)
            .seed_mode(SeedMode::PerTrial)
            .build()
            .expect("valid trace sweep scenario");
        // Trace trials are seconds-scale at large N: lower the sample
        // floor so one row never dominates the run.
        let r = Bench::new(format!("mc trace cec n{n} x{trace_trials}"))
            .samples(5, 10_000)
            .run(|| trace_sc.run().expect("trace engine reports failures per trial"));
        r.print();
        report.push(&r, &[("n", n as f64)]);
    }

    println!("\n-- cluster engine: event-driven coordinator, latency-only workers --");
    // The real reactor + worker threads + sharded ledger at sweep-scale N,
    // with subtask gemms replaced by their (scaled) cost-model sleeps: the
    // row tracks protocol/ledger overhead, not numerics. Quick mode trims
    // the fleet (640 thread spawns per sample cost tens of ms).
    let cluster_n = if hcec::bench::quick_mode() { 160 } else { 640 };
    let cluster_sc = Scenario::builder(&format!("bench_cluster_sim_n{cluster_n}"))
        .engine(Engine::Cluster)
        .job(job)
        .fleet(cluster_n, cluster_n)
        .schemes(vec![SchemeConfig::Cec { k: 10, s: 20 }])
        .cluster(hcec::scenario::ClusterSpec {
            backend: hcec::scenario::ClusterBackendSpec::SimulatedLatency,
            time_scale: 0.05,
            preempt_after_first: 0,
            backfill: hcec::scenario::BackfillSpec::On,
        })
        .trials(1)
        .seed(11)
        .build()
        .expect("valid cluster bench scenario");
    let r = Bench::new(format!("cluster sim cec n{cluster_n} x1"))
        .samples(3, 50)
        .run(|| cluster_sc.run().expect("fixed-fleet cluster cannot fail"));
    r.print();
    // Completions credited per run: every set needs K = 10.
    let events = (cluster_n * 10) as f64;
    println!("    -> {:.2e} protocol events/s", events_per_sec(&r, events));
    report.push(
        &r,
        &[("n", cluster_n as f64), ("protocol_events_per_sec", events_per_sec(&r, events))],
    );

    // Same fleet under mid-job Poisson churn with the elastic planner's
    // re-balancing on (leave-backfill + join-shed): the delta vs the fixed
    // row tracks re-planning overhead, not numerics.
    let churn_tau = cost.worker_time(
        Cec::new(10, 20).subtask_ops(job.u, job.w, job.v, cluster_n),
        1.0,
    );
    let churn_horizon = 2.0 * 20.0 * churn_tau;
    let backfill_sc = Scenario::builder(&format!("bench_cluster_backfill_n{cluster_n}"))
        .engine(Engine::Cluster)
        .job(job)
        .fleet(cluster_n, cluster_n)
        .schemes(vec![SchemeConfig::Cec { k: 10, s: 20 }])
        .elasticity(ElasticitySpec::Churn {
            n_min: cluster_n / 2,
            n_initial: cluster_n,
            rate: 0.25 * cluster_n as f64 / churn_horizon,
            horizon: churn_horizon,
            reassign: Reassign::Identity,
        })
        .cluster(hcec::scenario::ClusterSpec {
            backend: hcec::scenario::ClusterBackendSpec::SimulatedLatency,
            time_scale: 0.05,
            preempt_after_first: 0,
            backfill: hcec::scenario::BackfillSpec::On,
        })
        .trials(1)
        .seed(11)
        .seed_mode(SeedMode::PerTrial)
        .build()
        .expect("valid cluster backfill bench scenario");
    let r = Bench::new(format!("cluster sim cec n{cluster_n} backfill"))
        .samples(3, 50)
        .run(|| backfill_sc.run().expect("cluster engine records failures per trial"));
    r.print();
    report.push(&r, &[("n", cluster_n as f64)]);

    println!("\n-- data plane: Arc'd dispatch, pooled frames, batched reactor --");
    // Paired rows measure each zero-copy mechanism against the legacy
    // behaviour it replaced, in one process (no env knobs): the clone arm
    // re-creates the old per-spawn operand copy, the fresh arm the old
    // allocate-per-frame wire encode, and the batch pair runs the same
    // fixed-fleet cluster job at drain cap 1 (the pre-batching oracle)
    // vs the default 64.
    use std::sync::Arc;
    let enc = Matrix::random(160, 3200, &mut rng); // one CEC share at n640
    let enc_arc = Arc::new(enc.clone());
    let task_rows = 0..enc.rows() / 20; // S = 20 subtasks per share
    let r = Bench::new("dispatch clone n640").run(|| enc.clone());
    r.print();
    report.push(&r, &[]);
    let mut scratch = Matrix::zeros(0, 0);
    let r = Bench::new("dispatch arc n640").run(|| {
        let shared = Arc::clone(&enc_arc);
        scratch.assign_rows(&shared, task_rows.clone());
        shared.rows()
    });
    r.print();
    println!("    -> arc dispatch stages one task, clone copies the whole share");
    report.push(&r, &[]);

    let done = hcec::coordinator::Event::SubtaskDone {
        slot: 3,
        group: 7,
        data: Some(vec![1.5f32; 1024]),
        elapsed: 0.25,
    };
    use hcec::coordinator::Wire;
    let r = Bench::new("frame encode fresh").run(|| done.to_wire());
    r.print();
    report.push(&r, &[]);
    let mut frame_buf = Vec::new();
    let r = Bench::new("frame encode pooled").run(|| {
        done.to_wire_into(&mut frame_buf);
        frame_buf.len()
    });
    r.print();
    println!("    -> pooled encode reuses one buffer; fresh allocates per frame");
    report.push(&r, &[]);

    use hcec::coordinator::{
        run_cluster_job, ClusterBackend, ClusterConfig, ClusterElasticity,
        SpeedSource, TransportConfig,
    };
    for batch in [1usize, 64] {
        let cfg = ClusterConfig {
            job,
            scheme: SchemeConfig::Cec { k: 10, s: 20 },
            n_max: cluster_n,
            n_workers: cluster_n,
            backend: ClusterBackend::Simulated { time_scale: 0.05 },
            speed: SpeedSource::Uniform,
            cost,
            elasticity: ClusterElasticity::Fixed,
            preempt_after_first: 0,
            backfill: true,
            chaos: None,
            transport: TransportConfig::default(),
            evt_batch: batch,
            seed: 11,
        };
        let r = Bench::new(format!("reactor batch{batch} n{cluster_n}"))
            .samples(3, 50)
            .run(|| run_cluster_job(&cfg).expect("fixed-fleet cluster cannot fail"));
        r.print();
        let events = (cluster_n * 10) as f64;
        println!("    -> {:.2e} protocol events/s", events_per_sec(&r, events));
        report.push(
            &r,
            &[
                ("n", cluster_n as f64),
                ("batch", batch as f64),
                ("protocol_events_per_sec", events_per_sec(&r, events)),
            ],
        );
    }

    if artifacts_available() {
        println!("\n-- PJRT execute latency (compiled-once artifacts) --");
        let mut rt = Runtime::open(default_artifact_dir()).unwrap();
        let _ = rt.matmul("subtask_mm_2x240x240", &a, &b); // compile outside timing
        Bench::new("pjrt subtask_mm_2x240x240").run(|| rt.matmul("subtask_mm_2x240x240", &a, &b).unwrap()).print();
        let _ = rt.matmul("direct_mm_240x240x240", &a2, &b);
        Bench::new("pjrt direct_mm_240x240x240").run(|| rt.matmul("direct_mm_240x240x240", &a2, &b).unwrap()).print();
    } else {
        println!("\n(skipping PJRT latency: run `make artifacts` and build with --features pjrt)");
    }

    let json_path = std::env::var("HCEC_BENCH_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_perf_stack.json").to_string()
    });
    match report.write(&json_path) {
        Ok(()) => println!("\nwrote {json_path}"),
        Err(e) => eprintln!("\ncould not write {json_path}: {e}"),
    }
}
