//! §Perf — whole-stack micro-benchmarks (EXPERIMENTS.md §Perf records the
//! before/after of the optimisation pass against these numbers).
//!
//! L3 targets (DESIGN.md §8): DES >= 1e6 subtask-events/s; allocation-free
//! event hot loop; decode dominated by the K·u·v combine, not the K x K
//! solve; PJRT execute latency small vs a 240-scale subtask.

use hcec::bench::{header, Bench, BenchResult};
use hcec::codes::RealMdsCode;
use hcec::linalg::{gemm, gemm_naive, Matrix};
use hcec::rng::default_rng;
use hcec::runtime::{artifacts_available, default_artifact_dir, Runtime};
use hcec::sim::{simulate_static, simulate_trace, CostModel, ElasticTrace, SpeedModel, WorkerSpeeds};
use hcec::tas::{Bicec, Cec, Mlcec, Scheme};
use hcec::workload::JobSpec;

fn events_per_sec(r: &BenchResult, events: f64) -> f64 {
    events / r.summary.mean
}

fn main() {
    header("perf_stack");
    let cost = CostModel::paper_default();
    let job = JobSpec::paper_square();
    let mut rng = default_rng(3);
    let speeds = WorkerSpeeds::sample(&SpeedModel::paper_default(), 40, &mut rng);

    println!("-- L3: DES hot path --");
    let cec = Cec::new(10, 20);
    let mlcec = Mlcec::new(10, 20);
    let bicec = Bicec::new(800, 80, 40);
    // One static run processes N*S (CEC/MLCEC) or N*S_b (BICEC) events.
    let r = Bench::new("simulate_static cec n40").run(|| simulate_static(&cec, 40, job, &cost, &speeds));
    r.print();
    println!("    -> {:.2e} subtask-events/s (target >= 1e6)", events_per_sec(&r, 800.0));
    let r = Bench::new("simulate_static mlcec n40").run(|| simulate_static(&mlcec, 40, job, &cost, &speeds));
    r.print();
    let r = Bench::new("simulate_static bicec n40").run(|| simulate_static(&bicec, 40, job, &cost, &speeds));
    r.print();
    println!("    -> {:.2e} subtask-events/s", events_per_sec(&r, 3200.0));

    println!("\n-- L3: elastic simulator (interval tracking) --");
    let small_job = JobSpec::new(240, 240, 240);
    let speeds8 = WorkerSpeeds::sample(&SpeedModel::paper_default(), 8, &mut rng);
    let tau = cost.worker_time(small_job.ops() / 16, 1.0);
    let trace = ElasticTrace::fig1(1.5 * tau, 3.0 * tau);
    let cec_small = Cec::new(2, 4);
    Bench::new("simulate_trace cec fig1")
        .run(|| simulate_trace(&cec_small, &trace, small_job, &cost, &speeds8).unwrap())
        .print();

    println!("\n-- L3: allocation (runs at every elastic event) --");
    Bench::new("mlcec allocate n40").run(|| mlcec.allocate(40)).print();

    println!("\n-- master decode: combine vs inverse split --");
    let code = RealMdsCode::new(12, 10);
    let data: Vec<Matrix> = (0..10).map(|_| Matrix::random(24, 240, &mut rng)).collect();
    let coded = code.encode(&data);
    let completed: Vec<(usize, &Matrix)> = (2..12).map(|i| (i, &coded[i])).collect();
    let r_dec = Bench::new("decode k10 (inverse + combine)").run(|| code.decode(&completed).unwrap());
    r_dec.print();
    let subset: Vec<usize> = (2..12).collect();
    let r_inv = Bench::new("inverse only").run(|| code.decode_coeffs_f32(&subset).unwrap());
    r_inv.print();
    println!(
        "    -> combine share of decode: {:.1}% (target: dominant)",
        100.0 * (1.0 - r_inv.summary.mean / r_dec.summary.mean)
    );

    println!("\n-- worker hot path: native gemm --");
    let a = Matrix::random(2, 240, &mut rng);
    let b = Matrix::random(240, 240, &mut rng);
    let r = Bench::new("gemm blocked 2x240x240").run(|| gemm(&a, &b));
    r.print();
    println!("    -> {:.2} Gmac/s", 2.0 * 240.0 * 240.0 / r.summary.mean / 1e9);
    let r = Bench::new("gemm naive   2x240x240").run(|| gemm_naive(&a, &b));
    r.print();
    let a2 = Matrix::random(240, 240, &mut rng);
    let r = Bench::new("gemm blocked 240x240x240").run(|| gemm(&a2, &b));
    r.print();
    println!("    -> {:.2} Gmac/s", 240.0f64.powi(3) / r.summary.mean / 1e9);

    if artifacts_available() {
        println!("\n-- PJRT execute latency (compiled-once artifacts) --");
        let mut rt = Runtime::open(default_artifact_dir()).unwrap();
        let _ = rt.matmul("subtask_mm_2x240x240", &a, &b); // compile outside timing
        Bench::new("pjrt subtask_mm_2x240x240").run(|| rt.matmul("subtask_mm_2x240x240", &a, &b).unwrap()).print();
        let _ = rt.matmul("direct_mm_240x240x240", &a2, &b);
        Bench::new("pjrt direct_mm_240x240x240").run(|| rt.matmul("direct_mm_240x240x240", &a2, &b).unwrap()).print();
    } else {
        println!("\n(skipping PJRT latency: run `make artifacts`)");
    }
}
