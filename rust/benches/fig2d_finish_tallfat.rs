//! Fig. 2d — average finishing time vs N, tall x fat (2400,960,6000).
//!
//! Paper headline: BICEC's decode (∝ K_bicec·u·v) erases its computation
//! edge at v = 6000; MLCEC is best for N ∈ {32..40} (~15% vs CEC at N=40).

use hcec::bench::header;
use hcec::config::ExperimentConfig;
use hcec::figures::fig2_table;
use hcec::metrics::write_csv;

fn trials() -> usize {
    std::env::var("HCEC_BENCH_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(20)
}

fn main() {
    header("fig2d_finish_tallfat");
    let cfg = ExperimentConfig { trials: trials(), ..Default::default() }.tall_fat();
    let table = fig2_table(&cfg, "2d");
    println!("{}", table.render());
    println!("paper: MLCEC best for N in 32..40 (-15% at N=40); BICEC loses its edge.");
    let _ = write_csv(&table, "results/fig2d.csv");
}
