//! Extension ablations Ext-T1..T3 (DESIGN.md §5): transition waste,
//! d-level policies, straggler-model robustness.

use hcec::bench::header;
use hcec::config::ExperimentConfig;
use hcec::figures::{
    dlevel_table, hetero_table, hierarchy_table, reassign_table, straggler_sweep_table,
    transition_waste_table,
};
use hcec::metrics::write_csv;

fn trials() -> usize {
    std::env::var("HCEC_BENCH_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(12)
}

fn main() {
    header("ext_ablations");
    let cfg = ExperimentConfig { trials: trials(), ..Default::default() };

    println!("-- Ext-T1: transition waste under Poisson elasticity --");
    let t1 = transition_waste_table(&cfg, 3.0);
    println!("{}", t1.render());
    println!("claim: BICEC waste = 0 exactly; CEC/MLCEC pay per re-allocation.\n");
    let _ = write_csv(&t1, "results/ext_t1_transition_waste.csv");

    println!("-- Ext-T2: MLCEC d-level policies (paper future work) --");
    let small = ExperimentConfig { trials: trials(), ns: vec![24, 32, 40], ..Default::default() };
    let t2 = dlevel_table(&small);
    println!("{}", t2.render());
    let _ = write_csv(&t2, "results/ext_t2_dlevels.csv");

    println!("-- Ext-T3: straggler-model robustness (Fig. 2c setup, N=40) --");
    let t3 = straggler_sweep_table(&cfg, &[2.0, 5.0, 10.0], &[0.25, 0.5, 0.75]);
    println!("{}", t3.render());
    println!(
        "finding: BICEC's finishing-time win needs *severe* straggling \
         (slowdown >= 5, p >= 0.5); with mild stragglers its decode cost \
         dominates and CEC/MLCEC win — consistent with the paper's Fig. 2d \
         mechanism."
    );
    let _ = write_csv(&t3, "results/ext_t3_straggler_sweep.csv");

    println!("\n-- Ext-T4: waste-minimising re-assignment ([10]) --");
    let t4 = reassign_table(&cfg, 3.0);
    println!("{}", t4.render());
    println!("claim: max_overlap never pays more waste than identity.\n");
    let _ = write_csv(&t4, "results/ext_t4_reassign.csv");

    println!("-- Ext-T5: hierarchy ladder (rate-matched groups, N=40) --");
    let t5 = hierarchy_table(&cfg);
    println!("{}", t5.render());
    println!("claim: within rate 5/8, MLCC's layers beat classic coding; within the\nelastic group, BICEC has the lowest computation time.\n");
    let _ = write_csv(&t5, "results/ext_t5_hierarchy.csv");

    println!("-- Ext-T6: heterogeneous-aware allocation ([11,12]) --");
    let t6 = hetero_table(&cfg);
    println!("{}", t6.render());
    println!("claim: speed-proportional selection wins at moderate skew (all N at\n<=50% slow; N>=32 at 75%); the N=24/75% corner is an honest limitation.");
    let _ = write_csv(&t6, "results/ext_t6_hetero.csv");
}
