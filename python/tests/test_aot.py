"""AOT pipeline tests: lowering round-trips, manifest format, preset shapes."""

import os
import re
import tempfile

import jax
import numpy as np

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

MANIFEST_RE = re.compile(
    r"^[a-z0-9_]+\|in=(f32\[[0-9,]+\];?)+\|out=f32\[[0-9,]+\]$")


def test_smoke_preset_builds_and_manifest_parses():
    with tempfile.TemporaryDirectory() as d:
        lines = aot.build(d, "smoke")
        assert len(lines) == 2
        for line in lines:
            assert MANIFEST_RE.match(line), line
        files = sorted(os.listdir(d))
        assert "manifest.txt" in files
        assert "smoke_mm_4x8x4.hlo.txt" in files
        # HLO text must start with an HloModule header the rust parser accepts.
        with open(os.path.join(d, "smoke_mm_4x8x4.hlo.txt")) as f:
            assert f.read().startswith("HloModule")


def test_end_to_end_preset_shapes_consistent():
    arts = aot.preset_end_to_end()
    names = [a[0] for a in arts]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for name, fn, args in arts:
        out = jax.eval_shape(fn, *args)
        assert all(d > 0 for d in out.shape), (name, out.shape)
    # The decode artifact must invert the CEC/MLCEC sub-task geometry:
    # K=10 blocks of (2, 240).
    decode = dict((a[0], a) for a in arts)["decode_k10_r2_v240"]
    assert tuple(decode[2][1].shape) == (10, 2, 240)


def test_lowered_hlo_executes_in_jax():
    """The lowered module, compiled back by jax, equals the eager model."""
    spec = aot.spec(4, 8), aot.spec(8, 4)
    lowered = jax.jit(model.subtask_product).lower(*spec)
    compiled = lowered.compile()
    rng = np.random.default_rng(0)
    a = rng.standard_normal((4, 8)).astype(np.float32)
    b = rng.standard_normal((8, 4)).astype(np.float32)
    np.testing.assert_allclose(
        compiled(a, b), model.subtask_product(a, b), rtol=1e-5, atol=1e-5)


def test_hlo_text_has_no_64bit_ids():
    """Regression guard for the xla_extension 0.5.1 proto-id limit: the text
    path must remain the interchange (ids are reassigned by the parser), and
    the emitted text must be non-trivial HLO."""
    lowered = jax.jit(model.subtask_product).lower(aot.spec(4, 8), aot.spec(8, 4))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "dot(" in text or "dot " in text or "fusion" in text
