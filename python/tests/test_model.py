"""L2 graph tests: entry-point shapes, fused-vs-composed equivalence, and a
full coded-computing round trip (encode -> subtask products -> decode)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_platform_name", "cpu")


def chebyshev_vandermonde(n, k):
    """(n, k) generator: rows evaluate polynomials at Chebyshev points —
    mirrors rust/src/codes/vandermonde.rs."""
    pts = np.cos((2 * np.arange(n) + 1) / (2 * n) * np.pi)
    return np.vander(pts, k, increasing=True).astype(np.float32)


def test_subtask_product_shape_and_value():
    a = jnp.full((2, 6), 0.5, jnp.float32)
    b = jnp.full((6, 4), 2.0, jnp.float32)
    out = model.subtask_product(a, b)
    assert out.shape == (2, 4)
    np.testing.assert_allclose(out, jnp.full((2, 4), 6.0))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fused_encode_product_matches_composed(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    gen = jax.random.normal(k1, (5, 3), jnp.float32)
    a_stack = jax.random.normal(k2, (3, 4, 6), jnp.float32)
    b = jax.random.normal(k3, (6, 8), jnp.float32)
    fused = model.encode_then_product(gen, a_stack, b)
    enc = model.encode_stack(gen, a_stack)
    composed = jnp.stack(
        [model.subtask_product(enc[i], b) for i in range(enc.shape[0])])
    np.testing.assert_allclose(fused, composed, rtol=1e-4, atol=1e-4)


def test_ref_mode_matches_kernel_mode():
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (8, 12), jnp.float32)
    b = jax.random.normal(k2, (12, 8), jnp.float32)
    np.testing.assert_allclose(
        model.subtask_product(a, b),
        model.subtask_product(a, b, ref_mode=True), rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       k=st.sampled_from([2, 4, 6, 8, 10]),
       extra=st.integers(0, 4))
def test_full_coded_round_trip(seed, k, extra):
    """The paper's pipeline at L2 granularity: partition A into k blocks,
    encode to n = k + extra coded blocks, multiply each by B, decode from an
    arbitrary k-subset of completed products, compare against direct A @ B."""
    n = k + extra
    rng = np.random.default_rng(seed)
    u, w, v = 4 * k, 16, 12
    a = rng.standard_normal((u, w)).astype(np.float32)
    b = rng.standard_normal((w, v)).astype(np.float32)

    a_stack = jnp.asarray(a.reshape(k, u // k, w))
    gen = chebyshev_vandermonde(n, k)
    encoded = model.encode_stack(jnp.asarray(gen), a_stack)  # (n, u/k, w)

    # every worker computes its product; an adversarial subset "finishes"
    products = jnp.stack(
        [model.subtask_product(encoded[i], jnp.asarray(b)) for i in range(n)])
    done = sorted(rng.choice(n, size=k, replace=False).tolist())

    sub = gen[done, :]  # (k, k) Vandermonde submatrix of the finishers
    inv = np.linalg.inv(sub.astype(np.float64)).astype(np.float32)
    decoded = model.decode_combine(jnp.asarray(inv), products[jnp.asarray(done)])

    direct = a @ b
    got = np.asarray(decoded).reshape(u, v)
    scale = max(1.0, float(np.abs(direct).max()))
    np.testing.assert_allclose(got / scale, direct / scale, atol=2e-2)


def test_decode_mxu_variant_matches():
    rng = np.random.default_rng(1)
    inv = rng.standard_normal((6, 6)).astype(np.float32)
    stack = rng.standard_normal((6, 3, 10)).astype(np.float32)
    np.testing.assert_allclose(
        model.decode_combine(jnp.asarray(inv), jnp.asarray(stack), mxu=True),
        model.decode_combine(jnp.asarray(inv), jnp.asarray(stack)),
        rtol=1e-4, atol=1e-4)
