"""Kernel vs ref allclose — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes of the Pallas kernels (interpret mode)
against the pure-jnp oracles in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref, tiling

jax.config.update("jax_platform_name", "cpu")

# Interpret-mode pallas is slow; keep dims small but structurally varied.
DIMS = st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16])
SEEDS = st.integers(0, 2**31 - 1)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- matmul --

@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=SEEDS,
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_matmul_matches_ref(m, k, n, seed, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = rand(k1, (m, k), dtype), rand(k2, (k, n), dtype)
    got = kernels.matmul(a, b)
    want = ref.matmul(a, b)
    assert got.shape == (m, n) and got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype))


def test_matmul_tiled_multistep_grid():
    """Shapes that force >1 grid step on every axis (accumulation path)."""
    a = jnp.arange(32 * 24, dtype=jnp.float32).reshape(32, 24) / 100.0
    b = jnp.arange(24 * 40, dtype=jnp.float32).reshape(24, 40) / 100.0
    got = kernels.matmul(a, b, block_m=8, block_k=6, block_n=10)
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-5, atol=1e-5)


def test_matmul_identity():
    a = jnp.eye(8, dtype=jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(0), (8, 5), jnp.float32)
    np.testing.assert_allclose(kernels.matmul(a, b), b, rtol=1e-6, atol=1e-6)


def test_matmul_rejects_contraction_mismatch():
    a = jnp.zeros((4, 5), jnp.float32)
    b = jnp.zeros((6, 4), jnp.float32)
    with pytest.raises(AssertionError):
        kernels.matmul(a, b)


def test_matmul_end_to_end_artifact_shape():
    """The exact shape the rust worker hot path executes: (2,240)x(240,240)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    a, b = rand(k1, (2, 240), jnp.float32), rand(k2, (240, 240), jnp.float32)
    np.testing.assert_allclose(
        kernels.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- combine --

@settings(max_examples=25, deadline=None)
@given(p=DIMS, k=DIMS, r=DIMS, c=DIMS, seed=SEEDS)
def test_coded_combine_matches_ref(p, k, r, c, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    coeffs = rand(k1, (p, k), jnp.float32)
    stack = rand(k2, (k, r, c), jnp.float32)
    got = kernels.coded_combine(coeffs, stack)
    np.testing.assert_allclose(
        got, ref.coded_combine(coeffs, stack), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(p=DIMS, k=DIMS, r=DIMS, c=DIMS, seed=SEEDS)
def test_coded_combine_mxu_matches_vpu(p, k, r, c, seed):
    """The MXU (matmul-shaped) and VPU combines are interchangeable."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    coeffs = rand(k1, (p, k), jnp.float32)
    stack = rand(k2, (k, r, c), jnp.float32)
    np.testing.assert_allclose(
        kernels.coded_combine_mxu(coeffs, stack),
        kernels.coded_combine(coeffs, stack), rtol=1e-4, atol=1e-4)


def test_combine_identity_coeffs_is_passthrough():
    stack = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 5), jnp.float32)
    out = kernels.coded_combine(jnp.eye(4, dtype=jnp.float32), stack)
    np.testing.assert_allclose(out, stack, rtol=1e-6, atol=1e-6)


def test_combine_single_block_scaling():
    stack = jnp.ones((1, 2, 2), jnp.float32)
    out = kernels.coded_combine(jnp.array([[3.0]], jnp.float32), stack)
    np.testing.assert_allclose(out, 3.0 * stack)


def test_combine_is_encode_decode_inverse():
    """coded_combine(V) then coded_combine(V^-1) recovers the data exactly
    (up to f32) — the algebraic heart of MDS coded computing."""
    rng = np.random.default_rng(0)
    k = 6
    # Chebyshev-point Vandermonde (what the rust codes/ module uses).
    pts = np.cos((2 * np.arange(k) + 1) / (2 * k) * np.pi)
    v = np.vander(pts, k, increasing=True).astype(np.float32)
    inv = np.linalg.inv(v.astype(np.float64)).astype(np.float32)
    data = rng.standard_normal((k, 4, 8)).astype(np.float32)
    enc = kernels.coded_combine(jnp.asarray(v), jnp.asarray(data))
    dec = kernels.coded_combine(jnp.asarray(inv), enc)
    np.testing.assert_allclose(dec, data, rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------- tiling --

@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 4096), cap=st.integers(1, 512))
def test_largest_divisor_divides_and_bounded(n, cap):
    d = tiling.largest_divisor_leq(n, cap)
    assert 1 <= d <= min(n, cap)
    assert n % d == 0


def test_matmul_tiles_divide_shape():
    for m, k, n in [(2, 240, 240), (240, 240, 240), (24, 240, 240), (7, 13, 3)]:
        bm, bk, bn = tiling.matmul_tiles(m, k, n)
        assert m % bm == 0 and k % bk == 0 and n % bn == 0


def test_vmem_budget_for_artifact_shapes():
    """DESIGN.md §Perf: each grid step's working set stays under 8 MiB."""
    for m, k, n in [(2, 240, 240), (24, 240, 240), (240, 240, 240)]:
        bm, bk, bn = tiling.matmul_tiles(m, k, n)
        assert tiling.vmem_bytes(bm, bk, bn) <= 8 * 2**20
