"""Tile-size selection shared by the Pallas kernels.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the MXU wants 128x128
operand tiles and the VPU lane width is 128, so we tile each dimension with
the largest divisor not exceeding the MXU-friendly cap. Shapes in this
project are always divisible by small factors (the coordinator zero-pads per
the paper), so the divisor search terminates at a sane tile quickly.
"""

MXU_TILE = 128
# Contraction-dim cap: 4 MXU passes per block keeps the VMEM working set of
# an (bm, bk) + (bk, bn) + (bm, bn) triple under ~1 MiB for f32.
K_TILE_CAP = 512


def largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of `n` that is <= `cap` (>=1)."""
    if n <= cap:
        return n
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


def matmul_tiles(m: int, k: int, n: int) -> tuple[int, int, int]:
    """(bm, bk, bn) tile sizes for an (m, k) x (k, n) product."""
    return (
        largest_divisor_leq(m, MXU_TILE),
        largest_divisor_leq(k, K_TILE_CAP),
        largest_divisor_leq(n, MXU_TILE),
    )


def vmem_bytes(bm: int, bk: int, bn: int, itemsize: int = 4) -> int:
    """Estimated VMEM working set of one matmul grid step (operands + acc)."""
    return itemsize * (bm * bk + bk * bn) + 4 * bm * bn
