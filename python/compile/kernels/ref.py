"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an entry here with identical
semantics; pytest (python/tests/) asserts allclose between the two across a
hypothesis-driven sweep of shapes and dtypes. These are also the L2
fallbacks: `model.py` can be built against the references (ref_mode=True) to
isolate kernel bugs from graph bugs.
"""

import jax.numpy as jnp


def matmul(a, b):
    """Plain product: (m, k) x (k, n) -> (m, n), f32 accumulate."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def coded_combine(coeffs, stack):
    """Linear combination of a stack of equal-shaped blocks.

    coeffs: (p, k) real combination matrix (encode generator rows or the
            inverse-Vandermonde rows used for decode).
    stack:  (k, r, c) the k blocks being combined.
    returns (p, r, c) with out[i] = sum_j coeffs[i, j] * stack[j].

    Encode and decode in MDS coded computing are the *same* contraction with
    different coefficient matrices, so one kernel serves both.
    """
    return jnp.einsum(
        "pk,krc->prc", coeffs, stack, preferred_element_type=jnp.float32
    ).astype(stack.dtype)


def encoded_subtask_product(a_block, b):
    """The per-worker hot path: one encoded subtask `Â_{n,m} @ B`."""
    return matmul(a_block, b)


def encode_then_product(coeffs, a_stack, b):
    """Fused encode + product: out[p] = (sum_k coeffs[p,k] A_k) @ B."""
    enc = coded_combine(coeffs, a_stack)  # (p, r, w)
    return jnp.einsum(
        "prw,wv->prv", enc, b, preferred_element_type=jnp.float32
    ).astype(b.dtype)
