"""L1 Pallas kernel: tiled matrix product — the per-worker hot path.

The paper's unit of work is one encoded subtask `Â_{n,m} @ B`. On TPU this
is an MXU-bound product; we tile for VMEM with BlockSpecs over a
(M/bm, N/bn, K/bk) grid and accumulate in f32. `interpret=True` everywhere:
the CPU PJRT plugin cannot run Mosaic custom-calls, so interpret-mode is the
correctness path and the TPU numbers in DESIGN.md §Perf are estimated from
the BlockSpec footprint (see `tiling.vmem_bytes`).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling


def _matmul_kernel(x_ref, y_ref, o_ref):
    # Grid axis 2 walks the contraction; zero the accumulator tile on the
    # first step, then accumulate an MXU-shaped partial product per step.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n"))
def matmul(a, b, *, block_m=None, block_k=None, block_n=None):
    """Tiled product (m, k) x (k, n) -> (m, n); f32 accumulation.

    Tile sizes default to the MXU-friendly divisors from `tiling`; callers
    (benches, hypothesis sweeps) may pin them to exercise specific shapes.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} x {b.shape}"
    bm0, bk0, bn0 = tiling.matmul_tiles(m, k, n)
    bm = block_m or bm0
    bk = block_k or bk0
    bn = block_n or bn0
    assert m % bm == 0 and k % bk == 0 and n % bn == 0

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
    return out.astype(a.dtype)
