"""L1 Pallas kernels (build-time only; lowered into the L2 HLO).

`matmul`   — tiled MXU product, the per-subtask hot path.
`combine`  — coded combine (MDS encode/decode contraction), VPU and MXU forms.
`ref`      — pure-jnp oracles; pytest asserts kernel == ref.
`tiling`   — shared tile-size selection + VMEM footprint estimate.
"""

from .combine import coded_combine, coded_combine_mxu  # noqa: F401
from .matmul import matmul  # noqa: F401
