"""L1 Pallas kernel: coded combine — MDS encode and decode.

Encode (generator rows x data blocks) and decode (inverse-Vandermonde rows x
completed encoded outputs) are the same contraction:

    out[p] = sum_k coeffs[p, k] * stack[k]        stack[k]: (r, c) blocks

On TPU this is VPU work (broadcast scalar x block, accumulate); the grid
walks (p, r-tiles, k) so each step holds one (br, c-tile) block in VMEM.
A matmul-shaped alternative (reshape stack to (k, r*c) and hit the MXU) is
provided as `coded_combine_mxu`; the figure benches compare both (DESIGN.md
Ext-T2 discussion of decode cost).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling
from .matmul import matmul


def _combine_kernel(c_ref, s_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # c_ref is a (1, 1) block: one scalar coefficient per grid step.
    o_ref[...] += c_ref[0, 0].astype(jnp.float32) * s_ref[0].astype(jnp.float32)


@jax.jit
def coded_combine(coeffs, stack):
    """out[p] = sum_k coeffs[p, k] * stack[k]; (p,k) x (k,r,c) -> (p,r,c)."""
    p, k = coeffs.shape
    k2, r, c = stack.shape
    assert k == k2, f"rank mismatch: {coeffs.shape} vs {stack.shape}"
    br = tiling.largest_divisor_leq(r, tiling.MXU_TILE)
    bc = tiling.largest_divisor_leq(c, tiling.MXU_TILE)

    out = pl.pallas_call(
        _combine_kernel,
        grid=(p, r // br, k),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, l: (i, l)),
            pl.BlockSpec((1, br, c), lambda i, j, l: (l, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, br, c), lambda i, j, l: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((p, r, c), jnp.float32),
        interpret=True,
    )(coeffs, stack)
    return out.astype(stack.dtype)


@jax.jit
def coded_combine_mxu(coeffs, stack):
    """Matmul-shaped combine: reshape blocks to rows and contract on the MXU.

    Profitable when k is large (BICEC: k = 800) — the VPU version walks the
    grid k times per output tile while this runs one (p, k) x (k, r*c)
    product with k-tiled accumulation.
    """
    p, k = coeffs.shape
    k2, r, c = stack.shape
    assert k == k2
    flat = stack.reshape(k, r * c)
    out = matmul(coeffs.astype(stack.dtype), flat)
    return out.reshape(p, r, c)
