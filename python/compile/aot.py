"""AOT lowering: JAX/Pallas (L2+L1) -> HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is one jitted entry point of `model.py` lowered at the concrete
shapes the rust coordinator executes. `manifest.txt` describes the I/O
signature of every artifact in a line format the rust side parses:

    <name>|in=f32[10,10];f32[10,2,240]|out=f32[10,2,240]

All modules are lowered with return_tuple=True, so outputs are 1-tuples on
the PJRT side (rust unwraps with `to_tuple1`).

Usage:  python -m compile.aot --out-dir ../artifacts [--preset end_to_end]
"""

import argparse
import os

import jax
from jax import numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


_DTYPE_NAMES = {"float32": "f32", "bfloat16": "bf16", "float64": "f64"}


def _fmt(s) -> str:
    dt = _DTYPE_NAMES.get(str(s.dtype), str(s.dtype))
    return f"{dt}[{','.join(str(d) for d in s.shape)}]"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Artifact presets.
#
# `end_to_end` matches examples/end_to_end.rs: (u, w, v) = (240, 240, 240),
# CEC/MLCEC with K = 10, N_max = 12 (encoded task Â_n: 24 rows -> 12 subtasks
# of 2 rows), BICEC with K = 24, S = 4 (48 encoded subtasks of 10 rows).
# `smoke` is a tiny set for fast pytest round-trips.
# ---------------------------------------------------------------------------

def preset_end_to_end():
    u = w = v = 240
    k, n_max = 10, 12
    rows_task = u // k             # 24 rows per encoded task
    rows_sub = rows_task // n_max  # 2 rows per CEC/MLCEC subtask
    kb = 24                        # BICEC code dimension (exact-recovery scale)
    rows_bic = u // kb             # 10 rows per BICEC subtask
    return [
        # (name, fn, example_args)
        ("subtask_mm_2x240x240", model.subtask_product,
         (spec(rows_sub, w), spec(w, v))),
        ("subtask_mm_10x240x240", model.subtask_product,
         (spec(rows_bic, w), spec(w, v))),
        ("task_mm_24x240x240", model.subtask_product,
         (spec(rows_task, w), spec(w, v))),
        ("direct_mm_240x240x240", model.direct_matmul,
         (spec(u, w), spec(w, v))),
        ("decode_k10_r2_v240", model.decode_combine,
         (spec(k, k), spec(k, rows_sub, v))),
        ("decode_k24_r10_v240", lambda c, s: model.decode_combine(c, s, mxu=True),
         (spec(kb, kb), spec(kb, rows_bic, v))),
        ("encode_n12_k10_r24_w240", model.encode_stack,
         (spec(n_max, k), spec(k, rows_task, w))),
        ("fused_encode_mm_n12_k10", model.encode_then_product,
         (spec(n_max, k), spec(k, rows_task, w), spec(w, v))),
    ]


def preset_smoke():
    return [
        ("smoke_mm_4x8x4", model.subtask_product, (spec(4, 8), spec(8, 4))),
        ("smoke_decode_k3_r2_v4", model.decode_combine,
         (spec(3, 3), spec(3, 2, 4))),
    ]


PRESETS = {"end_to_end": preset_end_to_end, "smoke": preset_smoke}


def build(out_dir: str, preset: str) -> list[str]:
    artifacts = PRESETS[preset]()
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, fn, args in artifacts:
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shape = jax.eval_shape(fn, *args)
        ins = ";".join(_fmt(a) for a in args)
        manifest_lines.append(f"{name}|in={ins}|out={_fmt(out_shape)}")
        print(f"  {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    return manifest_lines


def main():
    p = argparse.ArgumentParser(description="AOT-lower HCEC model entry points")
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--preset", default="end_to_end", choices=sorted(PRESETS))
    # Back-compat with the original Makefile target signature (--out FILE).
    p.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = p.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    lines = build(out_dir or ".", args.preset)
    print(f"wrote {len(lines)} artifacts + manifest to {out_dir}")


if __name__ == "__main__":
    main()
