"""L2: the paper's compute graph in JAX, calling the L1 Pallas kernels.

Hierarchical coded elastic computing decomposes `g(x) = A @ B` into linear
pieces, MDS-encodes them, and recovers from any K completed pieces. The
graph entry points here are what the rust coordinator executes via PJRT:

  subtask_product      — one encoded subtask `Â_{n,m} @ B` (worker hot path)
  decode_combine       — inverse-Vandermonde rows x completed outputs
  encode_stack         — generator rows x data blocks (master, setup path)
  encode_then_product  — fused encode+product (ablation: skips the encoded-A
                         materialisation round-trip through HBM)
  direct_matmul        — uncoded product, the verification baseline

Each is lowered once by `aot.py` at the concrete shapes the coordinator
needs and never re-traced at runtime. `ref_mode=True` swaps the Pallas
kernels for the pure-jnp oracles to isolate kernel bugs from graph bugs.
"""

from . import kernels
from .kernels import ref


def _impl(ref_mode: bool):
    return ref if ref_mode else kernels


def subtask_product(a_block, b, *, ref_mode: bool = False):
    """One encoded subtask: (r, w) x (w, v) -> (r, v)."""
    if ref_mode:
        return ref.matmul(a_block, b)
    return kernels.matmul(a_block, b)


def decode_combine(inv_rows, y_stack, *, ref_mode: bool = False, mxu: bool = False):
    """Recover original blocks from K completed encoded outputs.

    inv_rows: (k, k) rows of the inverse of the Vandermonde submatrix for
              the k workers that finished; y_stack: (k, r, v) their outputs.
    With `mxu=True` uses the matmul-shaped combine (wins for large k, i.e.
    BICEC's k=800 — see combine.py).
    """
    if ref_mode:
        return ref.coded_combine(inv_rows, y_stack)
    fn = kernels.coded_combine_mxu if mxu else kernels.coded_combine
    return fn(inv_rows, y_stack)


def encode_stack(gen_rows, a_stack, *, ref_mode: bool = False, mxu: bool = False):
    """Encode K data blocks into P coded blocks: (p,k) x (k,r,w) -> (p,r,w)."""
    if ref_mode:
        return ref.coded_combine(gen_rows, a_stack)
    fn = kernels.coded_combine_mxu if mxu else kernels.coded_combine
    return fn(gen_rows, a_stack)


def encode_then_product(gen_rows, a_stack, b, *, ref_mode: bool = False):
    """Fused encode + product: out[p] = (sum_k gen[p,k] A_k) @ B.

    One HLO module instead of two; XLA fuses the combine into the matmul's
    producer so the encoded Â never round-trips through HBM.
    """
    if ref_mode:
        return ref.encode_then_product(gen_rows, a_stack, b)
    p, k = gen_rows.shape
    _, r, w = a_stack.shape
    enc = kernels.coded_combine(gen_rows, a_stack)  # (p, r, w)
    return kernels.matmul(enc.reshape(p * r, w), b).reshape(p, r, -1)


def direct_matmul(a, b, *, ref_mode: bool = False):
    """Uncoded A @ B — end-to-end verification baseline."""
    return subtask_product(a, b, ref_mode=ref_mode)
